//! PJRT runtime: load the AOT-lowered HLO **text** artifacts and execute
//! them from the coordinator's round loop. Python never runs here.
//!
//! Pattern (see /opt/xla-example/load_hlo and DESIGN.md): text →
//! `HloModuleProto::from_text_file` → `XlaComputation::from_proto` →
//! `PjRtClient::compile` → `execute`. All entry points were lowered with
//! `return_tuple=True`, so every output is a tuple literal.

pub mod manifest;

use std::path::Path;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use anyhow::{anyhow, Context, Result};

pub use manifest::{artifacts_dir, load_profile, ProfileInfo};

/// Compiled executables for one model profile.
///
/// A single `Runtime` is shared by reference across the round engine's
/// worker threads (`fl::exec`), which call [`Runtime::train_step`] /
/// [`Runtime::quantize`] concurrently for different clients.
pub struct Runtime {
    client: xla::PjRtClient,
    /// The loaded profile's metadata.
    pub info: ProfileInfo,
    init: xla::PjRtLoadedExecutable,
    train_step: xla::PjRtLoadedExecutable,
    eval_step: xla::PjRtLoadedExecutable,
    quantize: xla::PjRtLoadedExecutable,
    /// Wall-time accounting (perf pass): cumulative **nanoseconds** per
    /// entry point, atomically accumulated so concurrent `execute`
    /// calls profile lock-free (was a `RefCell`, which kept the whole
    /// round loop single-threaded).
    exec_nanos: ExecClock,
    /// Escape hatch: `QCCF_PJRT_SERIALIZE=1` wraps every execute in a
    /// process-wide lock for PJRT plugins that are not safe under
    /// concurrent `Execute` (the bundled CPU client is).
    exec_lock: Option<Mutex<()>>,
}

// SAFETY: all interior mutability in `Runtime` is the [`ExecClock`]
// atomic profiling counters (whose cross-thread contract is exercised
// under Miri by `miri_exec_clock_concurrent_adds_are_exact` below) and
// the optional serialization mutex; the remaining fields are immutable
// after `load`. Two layers must be race-free for this to be sound:
// (1) PJRT itself — its API contract makes clients and loaded
// executables thread-safe (concurrent `Execute` on one
// `PjRtLoadedExecutable` is supported; the CPU plugin synchronizes
// internally); (2) the `xla` binding layer, which wraps raw handles
// and does not derive `Send`/`Sync` — this impl asserts its handle
// types are not non-atomically reference-counted. That second claim is
// checked empirically by `integration_runtime.rs::
// concurrent_execute_matches_serial`; if a binding revision ever
// introduces `Rc`-style sharing, set `QCCF_PJRT_SERIALIZE=1` (coarse
// per-execute lock) while the binding is fixed — the rest of the
// parallel round pipeline keeps working.
unsafe impl Send for Runtime {}
unsafe impl Sync for Runtime {}

/// The lock-free per-entry-point nanosecond clock behind
/// [`Runtime::exec_profile`]: one atomic cumulative-nanos counter per
/// entry point `(init, train_step, eval, quantize)`.
///
/// Split out of `Runtime` so the concurrency contract the
/// `unsafe impl Send/Sync` above leans on is testable in isolation —
/// including under Miri, which cannot construct a full `Runtime` (that
/// needs PJRT artifacts and the xla FFI). Profiling only: the clock
/// never feeds a decision, so nothing here can move a trace bit.
#[derive(Debug)]
pub struct ExecClock {
    nanos: [AtomicU64; 4],
}

impl ExecClock {
    /// A clock with all four counters at zero.
    pub const fn new() -> ExecClock {
        ExecClock {
            nanos: [
                AtomicU64::new(0),
                AtomicU64::new(0),
                AtomicU64::new(0),
                AtomicU64::new(0),
            ],
        }
    }

    /// Add `nanos` to entry point `which` (0..4). Relaxed is enough:
    /// counters are independent and only ever read as point-in-time
    /// snapshots, never used for synchronization.
    pub fn add(&self, which: usize, nanos: u64) {
        self.nanos[which].fetch_add(nanos, Ordering::Relaxed);
    }

    /// Point-in-time copy of all four counters (checkpoint capture).
    pub fn snapshot(&self) -> [u64; 4] {
        [
            self.nanos[0].load(Ordering::Relaxed),
            self.nanos[1].load(Ordering::Relaxed),
            self.nanos[2].load(Ordering::Relaxed),
            self.nanos[3].load(Ordering::Relaxed),
        ]
    }

    /// Reinstall a captured snapshot (checkpoint resume).
    pub fn restore(&self, nanos: [u64; 4]) {
        for (ctr, v) in self.nanos.iter().zip(nanos) {
            ctr.store(v, Ordering::Relaxed);
        }
    }

    /// The counters in seconds, the unit `exec_profile` reports.
    pub fn profile_secs(&self) -> [f64; 4] {
        self.snapshot().map(|n| n as f64 * 1e-9)
    }
}

impl Default for ExecClock {
    fn default() -> ExecClock {
        ExecClock::new()
    }
}

/// Result of one local training round on a client.
#[derive(Clone, Debug)]
pub struct TrainOut {
    /// Updated local model after τ steps.
    pub theta: Vec<f32>,
    /// Mean loss over the τ steps.
    pub mean_loss: f32,
    /// Per-step gradient norms.
    pub gnorms: Vec<f32>,
}

fn compile(client: &xla::PjRtClient, path: &Path) -> Result<xla::PjRtLoadedExecutable> {
    let proto = xla::HloModuleProto::from_text_file(path)
        .map_err(|e| anyhow!("parse {path:?}: {e:?}"))?;
    let comp = xla::XlaComputation::from_proto(&proto);
    client.compile(&comp).map_err(|e| anyhow!("compile {path:?}: {e:?}"))
}

impl Runtime {
    /// Load + compile all entry points of `profile` from `dir`.
    pub fn load(dir: &Path, profile: &str) -> Result<Runtime> {
        let info = load_profile(dir, profile).map_err(|e| anyhow!(e))?;
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("pjrt cpu client: {e:?}"))?;
        let get = |name: &str| -> Result<xla::PjRtLoadedExecutable> {
            let path = info
                .file(name)
                .ok_or_else(|| anyhow!("artifact `{name}` missing from manifest"))?;
            compile(&client, path).with_context(|| format!("loading `{name}`"))
        };
        Ok(Runtime {
            init: get("init")?,
            train_step: get("train_step")?,
            eval_step: get("eval_step")?,
            quantize: get("quantize")?,
            client,
            info,
            exec_nanos: ExecClock::new(),
            exec_lock: matches!(
                std::env::var("QCCF_PJRT_SERIALIZE").as_deref(),
                Ok("1")
            )
            .then(|| Mutex::new(())),
        })
    }

    /// Load from the default artifacts dir.
    pub fn load_default(profile: &str) -> Result<Runtime> {
        Self::load(&artifacts_dir(), profile)
    }

    /// PJRT platform name (diagnostics).
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    fn run(
        &self,
        which: usize,
        exe: &xla::PjRtLoadedExecutable,
        args: &[xla::Literal],
    ) -> Result<Vec<xla::Literal>> {
        let t0 = std::time::Instant::now();
        let _serial = self.exec_lock.as_ref().map(|m| m.lock().unwrap());
        let out = exe
            .execute::<xla::Literal>(args)
            .map_err(|e| anyhow!("execute: {e:?}"))?;
        let lit = out[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetch result: {e:?}"))?;
        let parts = lit.to_tuple().map_err(|e| anyhow!("untuple: {e:?}"))?;
        self.exec_nanos.add(which, t0.elapsed().as_nanos() as u64);
        Ok(parts)
    }

    fn f32_vec(lit: &xla::Literal) -> Result<Vec<f32>> {
        lit.to_vec::<f32>().map_err(|e| anyhow!("to_vec f32: {e:?}"))
    }

    fn f32_scalar(lit: &xla::Literal) -> Result<f32> {
        Ok(Self::f32_vec(lit)?[0])
    }

    fn theta_literal(&self, theta: &[f32]) -> Result<xla::Literal> {
        anyhow::ensure!(
            theta.len() == self.info.z,
            "theta length {} != Z {}",
            theta.len(),
            self.info.z
        );
        Ok(xla::Literal::vec1(theta))
    }

    /// `init() -> θ⁰` — the deterministic initial global model.
    pub fn init(&self) -> Result<Vec<f32>> {
        let parts = self.run(0, &self.init, &[])?;
        Self::f32_vec(&parts[0])
    }

    /// One client's τ local SGD steps (paper eq. (1)).
    ///
    /// `xs`: `tau*batch*pix` floats, `ys`: `tau*batch` labels.
    pub fn train_step(&self, theta: &[f32], xs: &[f32], ys: &[i32], lr: f32) -> Result<TrainOut> {
        let i = &self.info;
        let (h, w, c) = i.image;
        anyhow::ensure!(xs.len() == i.tau * i.batch * i.pix(), "xs size");
        anyhow::ensure!(ys.len() == i.tau * i.batch, "ys size");
        let xs = xla::Literal::vec1(xs)
            .reshape(&[i.tau as i64, i.batch as i64, h as i64, w as i64, c as i64])
            .map_err(|e| anyhow!("reshape xs: {e:?}"))?;
        let ys = xla::Literal::vec1(ys)
            .reshape(&[i.tau as i64, i.batch as i64])
            .map_err(|e| anyhow!("reshape ys: {e:?}"))?;
        let args = [self.theta_literal(theta)?, xs, ys, xla::Literal::scalar(lr)];
        let parts = self.run(1, &self.train_step, &args)?;
        Ok(TrainOut {
            theta: Self::f32_vec(&parts[0])?,
            mean_loss: Self::f32_scalar(&parts[1])?,
            gnorms: Self::f32_vec(&parts[2])?,
        })
    }

    /// One masked eval chunk: returns `(sum_loss, n_correct, n_valid)`.
    pub fn eval_chunk(&self, theta: &[f32], x: &[f32], y: &[i32], wmask: &[f32]) -> Result<(f64, f64, f64)> {
        let i = &self.info;
        let (h, w, c) = i.image;
        anyhow::ensure!(x.len() == i.eval_batch * i.pix(), "x size");
        let x = xla::Literal::vec1(x)
            .reshape(&[i.eval_batch as i64, h as i64, w as i64, c as i64])
            .map_err(|e| anyhow!("reshape x: {e:?}"))?;
        let y = xla::Literal::vec1(y);
        let wl = xla::Literal::vec1(wmask);
        let args = [self.theta_literal(theta)?, x, y, wl];
        let parts = self.run(2, &self.eval_step, &args)?;
        Ok((
            Self::f32_scalar(&parts[0])? as f64,
            Self::f32_scalar(&parts[1])? as f64,
            Self::f32_scalar(&parts[2])? as f64,
        ))
    }

    /// Evaluate over a whole test set (chunked + padded). Returns
    /// `(mean_loss, accuracy)`.
    pub fn evaluate(&self, theta: &[f32], images: &[f32], labels: &[i32]) -> Result<(f64, f64)> {
        let i = &self.info;
        let pix = i.pix();
        let n = labels.len();
        let eb = i.eval_batch;
        let (mut loss, mut correct, mut total) = (0.0, 0.0, 0.0);
        let mut off = 0;
        while off < n {
            let take = (n - off).min(eb);
            let mut x = vec![0.0f32; eb * pix];
            let mut y = vec![0i32; eb];
            let mut wm = vec![0.0f32; eb];
            x[..take * pix].copy_from_slice(&images[off * pix..(off + take) * pix]);
            y[..take].copy_from_slice(&labels[off..off + take]);
            for v in wm.iter_mut().take(take) {
                *v = 1.0;
            }
            let (l, c, t) = self.eval_chunk(theta, &x, &y, &wm)?;
            loss += l;
            correct += c;
            total += t;
            off += take;
        }
        anyhow::ensure!(total > 0.0, "empty test set");
        Ok((loss / total, correct / total))
    }

    /// Stochastic quantization through the Pallas kernel artifact
    /// (paper eq. (4)). Returns `(dequantized θ, θ^max)`.
    pub fn quantize(&self, theta: &[f32], noise: &[f32], q: f32) -> Result<(Vec<f32>, f32)> {
        anyhow::ensure!(noise.len() == theta.len(), "noise size");
        let args = [
            self.theta_literal(theta)?,
            xla::Literal::vec1(noise),
            xla::Literal::scalar(q),
        ];
        let parts = self.run(3, &self.quantize, &args)?;
        Ok((Self::f32_vec(&parts[0])?, Self::f32_scalar(&parts[1])?))
    }

    /// Cumulative execution seconds per entry point
    /// `(init, train_step, eval, quantize)` — perf-pass accounting.
    pub fn exec_profile(&self) -> [f64; 4] {
        self.exec_nanos.profile_secs()
    }

    /// The raw nanosecond clock behind [`Runtime::exec_profile`] —
    /// captured into checkpoints so a resumed run's profile continues
    /// the original accounting instead of restarting at zero.
    pub fn exec_nanos_snapshot(&self) -> [u64; 4] {
        self.exec_nanos.snapshot()
    }

    /// Reinstall a captured nanosecond clock (checkpoint resume).
    /// Profiling only — the clock never feeds any decision, so this
    /// cannot move a trace bit.
    pub fn restore_exec_nanos(&self, nanos: [u64; 4]) {
        self.exec_nanos.restore(nanos)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // Prefixed `miri_` so verify.sh's nightly gate can run exactly this
    // subset (`cargo +nightly miri test --lib miri_`): it exercises the
    // cross-thread contract the `unsafe impl Send/Sync for Runtime`
    // SAFETY argument leans on, without needing PJRT artifacts.
    #[test]
    fn miri_exec_clock_concurrent_adds_are_exact() {
        let threads: u64 = if cfg!(miri) { 4 } else { 8 };
        let iters: u64 = if cfg!(miri) { 50 } else { 10_000 };
        let clock = ExecClock::new();
        std::thread::scope(|s| {
            for t in 0..threads {
                let clock = &clock;
                s.spawn(move || {
                    for i in 0..iters {
                        clock.add(((t + i) % 4) as usize, 3);
                    }
                });
            }
        });
        let total: u64 = clock.snapshot().iter().sum();
        assert_eq!(total, threads * iters * 3, "lost or duplicated adds");
    }

    #[test]
    fn miri_exec_clock_snapshot_restore_round_trip() {
        let clock = ExecClock::new();
        clock.add(0, 7);
        clock.add(2, 11);
        clock.add(3, 13);
        let snap = clock.snapshot();
        assert_eq!(snap, [7, 0, 11, 13]);
        let resumed = ExecClock::default();
        resumed.restore(snap);
        assert_eq!(resumed.snapshot(), snap);
        let secs = resumed.profile_secs();
        assert_eq!(secs[3], 13.0 * 1e-9);
    }
}
