//! Repo tooling for the QCCF reproduction. The only task today is
//! [`detlint`], the determinism & safety audit `verify.sh` gates on:
//!
//! ```text
//! cargo run --manifest-path rust/xtask/Cargo.toml -p xtask -- detlint --root rust/src
//! ```
//!
//! See `docs/DETERMINISM.md` for the contract the rules machine-check.

pub mod detlint;
