//! `detlint` — the repo-specific determinism & safety audit.
//!
//! Every number this reproduction reports rests on one contract: runs
//! are **bit-identical** for any `--threads` value, any cache state,
//! and across checkpoint/resume (docs/DETERMINISM.md). The example
//! pins in `rust/tests/` enforce that contract by sampling it; this
//! pass enforces the *source patterns* that break it, at `verify.sh`
//! time instead of in a flaky `stress-100k` trace:
//!
//! * **R1** — no iteration over `HashMap`/`HashSet` outside the
//!   allowlisted memo modules (`sched/ctx.rs`, `sched/classes.rs`,
//!   `ga/mod.rs`, which only do bit-keyed *lookups*): hash order is
//!   nondeterministic, so folds/loops must go through `BTreeMap` or a
//!   sorted view.
//! * **R2** — no `Instant::now` / `SystemTime` outside `runtime/`,
//!   `bench.rs`, `util/logging.rs` and `obs/`: wall-clock flows through
//!   `Runtime` (or the `obs` telemetry layer) so it can be snapshotted
//!   and never feeds a decision.
//! * **R3** — float comparisons via `total_cmp` only: a
//!   `partial_cmp(..).unwrap()` sort is a NaN panic waiting in a hot
//!   path, and `unwrap_or(Equal)` fallbacks silently destabilize order.
//! * **R4** — RNG construction only through `util::rng` seeded
//!   streams: no `thread_rng`/entropy/`RandomState`-style ambient
//!   randomness anywhere.
//! * **R5** — file writes only through `util::fsio::replace_atomic`
//!   (writes staged *inside* a `replace_atomic` closure are
//!   recognized): a torn file on preemption must never be observable.
//! * **R6** — every `unsafe` block/impl carries a `// SAFETY:` comment
//!   immediately above (consecutive `unsafe impl`s may share one).
//! * **R7** — no `obs` wall-clock type (`SpanGuard`, `Stopwatch`,
//!   `LedgerEntry`, or any `obs::spans`/`obs::wall`/`obs::ledger`
//!   path) inside `metrics/` or `ckpt/`: those modules produce the
//!   bit-identical outputs, so wall-clock telemetry must stay at the
//!   call sites that bracket them (docs/OBSERVABILITY.md).
//!
//! Legitimate exceptions are *auditable, not invisible*: a
//! `// detlint: allow(Rk) — reason` comment on the offending line (or
//! the comment block directly above it) suppresses the finding, the
//! reason is mandatory, and the per-rule escape counts are printed in
//! the summary line so drift shows up in CI logs.
//!
//! The analysis is a comment/string-aware token scan, not a full parse
//! (the containers are offline, so `syn` is unavailable); `#[cfg(test)]
//! mod` blocks are skipped — tests are example pins and may compare
//! however they like.

use std::collections::BTreeMap;
use std::fs;
use std::io;
use std::path::{Path, PathBuf};

/// The rule identifiers accepted by `allow(..)` escapes, in report order.
pub const RULE_IDS: [&str; 7] = ["R1", "R2", "R3", "R4", "R5", "R6", "R7"];

/// Files (relative to the lint root) where hash-container use is legal:
/// the bit-keyed memo subsystems, which never iterate for results.
const R1_ALLOWLIST: [&str; 3] = ["sched/ctx.rs", "sched/classes.rs", "ga/mod.rs"];

/// Methods that observe hash iteration order.
const R1_ITER_METHODS: [&str; 10] = [
    ".iter()",
    ".iter_mut()",
    ".keys()",
    ".values()",
    ".values_mut()",
    ".into_iter()",
    ".into_keys()",
    ".into_values()",
    ".drain(",
    ".retain(",
];

/// Ambient / nondeterministic randomness sources (R4).
const R4_TOKENS: [&str; 8] = [
    "thread_rng",
    "from_entropy",
    "from_os_rng",
    "OsRng",
    "getrandom",
    "RandomState",
    "StdRng",
    "SmallRng",
];

/// Raw file-creation APIs (R5).
const R5_TOKENS: [&str; 4] = ["File::create", "File::create_new", "fs::write", "OpenOptions"];

/// One finding, anchored to a source line.
#[derive(Clone, Debug)]
pub struct Violation {
    /// Path relative to the lint root (or the bare file name).
    pub file: String,
    /// 1-based line number.
    pub line: usize,
    /// `R1`..`R7`, or `escape` for a malformed allow escape.
    pub rule: String,
    /// Human-readable description with the suggested fix.
    pub msg: String,
}

/// The aggregated result of one lint run.
#[derive(Debug, Default)]
pub struct Report {
    /// Findings that survived escapes, sorted by (file, line, rule).
    pub violations: Vec<Violation>,
    /// How many findings each rule's `allow` escapes suppressed.
    pub escapes_used: BTreeMap<String, usize>,
    /// Number of `.rs` files scanned.
    pub files: usize,
}

impl Report {
    /// The one-line summary verify.sh prints: per-rule escape counts so
    /// allow-drift is visible in logs.
    pub fn summary_line(&self) -> String {
        let escapes: Vec<String> = RULE_IDS
            .iter()
            .map(|r| format!("{r}={}", self.escapes_used.get(*r).copied().unwrap_or(0)))
            .collect();
        format!(
            "detlint: {} file(s) scanned, {} violation(s); allow escapes used: {}",
            self.files,
            self.violations.len(),
            escapes.join(" ")
        )
    }
}

/// Lint `root` (a directory walked recursively for `*.rs`, or a single
/// file). Paths in the report are relative to `root`.
pub fn lint_root(root: &Path) -> io::Result<Report> {
    let mut files = Vec::new();
    collect_rs(root, &mut files)?;
    files.sort();
    let mut rep = Report::default();
    for f in &files {
        let rel = match f.strip_prefix(root) {
            Ok(p) if !p.as_os_str().is_empty() => p.to_string_lossy().replace('\\', "/"),
            _ => f
                .file_name()
                .map(|n| n.to_string_lossy().into_owned())
                .unwrap_or_else(|| f.to_string_lossy().into_owned()),
        };
        let src = fs::read_to_string(f)?;
        rep.files += 1;
        lint_into(&rel, &src, &mut rep);
    }
    Ok(rep)
}

/// Lint a single in-memory source (tests and tooling).
pub fn lint_source_str(rel: &str, src: &str) -> Report {
    let mut rep = Report { files: 1, ..Report::default() };
    lint_into(rel, src, &mut rep);
    rep
}

fn collect_rs(p: &Path, out: &mut Vec<PathBuf>) -> io::Result<()> {
    if p.is_file() {
        out.push(p.to_path_buf());
        return Ok(());
    }
    let mut entries: Vec<PathBuf> = fs::read_dir(p)?
        .map(|e| e.map(|e| e.path()))
        .collect::<Result<_, _>>()?;
    entries.sort();
    for e in entries {
        if e.is_dir() {
            collect_rs(&e, out)?;
        } else if e.extension().is_some_and(|x| x == "rs") {
            out.push(e);
        }
    }
    Ok(())
}

// ---------------------------------------------------------------------------
// Source masking: split every line into (code, comment), with string and
// char literal *contents* blanked out of the code half so token scans
// cannot be fooled by literals, and comments preserved verbatim for the
// SAFETY / escape checks. Handles nested block comments, raw strings,
// byte strings, and the char-literal-vs-lifetime ambiguity.
// ---------------------------------------------------------------------------

#[derive(Clone, Debug, Default)]
struct MaskedLine {
    code: String,
    comment: String,
}

fn is_ident_char(c: char) -> bool {
    c.is_ascii_alphanumeric() || c == '_'
}

fn mask_source(src: &str) -> Vec<MaskedLine> {
    let cs: Vec<char> = src.chars().collect();
    let n = cs.len();
    let mut lines: Vec<MaskedLine> = vec![MaskedLine::default()];

    fn push(lines: &mut Vec<MaskedLine>, c: char, to_comment: bool) {
        if c == '\n' {
            lines.push(MaskedLine::default());
        } else if to_comment {
            lines.last_mut().expect("lines never empty").comment.push(c);
        } else {
            lines.last_mut().expect("lines never empty").code.push(c);
        }
    }

    let mut i = 0usize;
    while i < n {
        let c = cs[i];

        // Line comment (covers `//`, `///`, `//!`).
        if c == '/' && i + 1 < n && cs[i + 1] == '/' {
            while i < n && cs[i] != '\n' {
                push(&mut lines, cs[i], true);
                i += 1;
            }
            continue;
        }

        // Block comment, nesting-aware.
        if c == '/' && i + 1 < n && cs[i + 1] == '*' {
            let mut depth = 0usize;
            while i < n {
                if cs[i] == '/' && i + 1 < n && cs[i + 1] == '*' {
                    depth += 1;
                    push(&mut lines, '/', true);
                    push(&mut lines, '*', true);
                    i += 2;
                } else if cs[i] == '*' && i + 1 < n && cs[i + 1] == '/' {
                    depth = depth.saturating_sub(1);
                    push(&mut lines, '*', true);
                    push(&mut lines, '/', true);
                    i += 2;
                    if depth == 0 {
                        break;
                    }
                } else {
                    push(&mut lines, cs[i], true);
                    i += 1;
                }
            }
            continue;
        }

        let prev_ident = i > 0 && is_ident_char(cs[i - 1]);

        // Raw (byte) string: r"..", r#".."#, br#".."# — blank contents.
        if !prev_ident && (c == 'r' || (c == 'b' && i + 1 < n && cs[i + 1] == 'r')) {
            let q_start = if c == 'b' { i + 2 } else { i + 1 };
            let mut j = q_start;
            let mut hashes = 0usize;
            while j < n && cs[j] == '#' {
                hashes += 1;
                j += 1;
            }
            if j < n && cs[j] == '"' {
                for _ in i..=j {
                    push(&mut lines, ' ', false);
                }
                i = j + 1;
                while i < n {
                    if cs[i] == '"' {
                        let closes = (0..hashes).all(|h| i + 1 + h < n && cs[i + 1 + h] == '#');
                        if closes {
                            for _ in 0..(1 + hashes) {
                                push(&mut lines, ' ', false);
                            }
                            i += 1 + hashes;
                            break;
                        }
                    }
                    push(&mut lines, if cs[i] == '\n' { '\n' } else { ' ' }, false);
                    i += 1;
                }
                continue;
            }
            // Not a raw string opener: fall through to normal handling.
        }

        // Plain / byte string literal — blank contents, keep the quotes.
        if c == '"' || (c == 'b' && !prev_ident && i + 1 < n && cs[i + 1] == '"') {
            if c == 'b' {
                push(&mut lines, ' ', false);
                i += 1;
            }
            push(&mut lines, '"', false);
            i += 1;
            while i < n {
                if cs[i] == '\\' && i + 1 < n {
                    push(&mut lines, ' ', false);
                    push(&mut lines, if cs[i + 1] == '\n' { '\n' } else { ' ' }, false);
                    i += 2;
                    continue;
                }
                if cs[i] == '"' {
                    push(&mut lines, '"', false);
                    i += 1;
                    break;
                }
                push(&mut lines, if cs[i] == '\n' { '\n' } else { ' ' }, false);
                i += 1;
            }
            continue;
        }

        // Char literal vs lifetime.
        if c == '\'' {
            if i + 1 < n && cs[i + 1] == '\\' {
                // Escaped char literal: blank through the closing quote.
                push(&mut lines, ' ', false);
                i += 1;
                while i < n && cs[i] != '\'' {
                    push(&mut lines, if cs[i] == '\n' { '\n' } else { ' ' }, false);
                    i += 1;
                }
                if i < n {
                    push(&mut lines, ' ', false);
                    i += 1;
                }
                continue;
            }
            if i + 2 < n && cs[i + 2] == '\'' && cs[i + 1] != '\'' && cs[i + 1] != '\\' {
                // Simple char literal 'x'.
                for _ in 0..3 {
                    push(&mut lines, ' ', false);
                }
                i += 3;
                continue;
            }
            // Lifetime tick: keep it, it cannot confuse token scans.
            push(&mut lines, '\'', false);
            i += 1;
            continue;
        }

        push(&mut lines, c, false);
        i += 1;
    }
    lines
}

// ---------------------------------------------------------------------------
// Token scanning.
// ---------------------------------------------------------------------------

/// Byte offsets at which `tok` occurs in `code` with identifier-boundary
/// checks on whichever ends of `tok` are identifier characters.
fn token_positions(code: &str, tok: &str) -> Vec<usize> {
    let mut out = Vec::new();
    if tok.is_empty() {
        return out;
    }
    let first_is_ident = tok.chars().next().is_some_and(is_ident_char);
    let last_is_ident = tok.chars().last().is_some_and(is_ident_char);
    let mut start = 0usize;
    while let Some(pos) = code[start..].find(tok) {
        let p = start + pos;
        let before_ok = !first_is_ident
            || p == 0
            || !code[..p].chars().next_back().is_some_and(is_ident_char);
        let after_ok = !last_is_ident
            || !code[p + tok.len()..].chars().next().is_some_and(is_ident_char);
        if before_ok && after_ok {
            out.push(p);
        }
        start = p + tok.len();
    }
    out
}

fn has_token(code: &str, tok: &str) -> bool {
    !token_positions(code, tok).is_empty()
}

// ---------------------------------------------------------------------------
// Escapes: `// detlint: allow(Rk) — reason`.
// ---------------------------------------------------------------------------

#[derive(Debug, Default)]
struct EscapeScan {
    rules: Vec<String>,
    malformed: Vec<String>,
}

fn is_reason_separator(c: char) -> bool {
    c.is_whitespace() || matches!(c, '—' | '–' | '-' | ':' | ',')
}

fn parse_escape_comment(comment: &str) -> EscapeScan {
    let mut out = EscapeScan::default();
    let mut rest = comment;
    while let Some(p) = rest.find("detlint:") {
        let tail = rest[p + "detlint:".len()..].trim_start();
        if let Some(t) = tail.strip_prefix("allow(") {
            if let Some(close) = t.find(')') {
                let rule = t[..close].trim().to_string();
                let reason = t[close + 1..].trim_start_matches(is_reason_separator).trim();
                if !RULE_IDS.contains(&rule.as_str()) {
                    out.malformed.push(format!(
                        "unknown rule `{rule}` in detlint allow escape (expected one of R1..R7)"
                    ));
                } else if reason.is_empty() {
                    out.malformed.push(format!(
                        "allow({rule}) escape without a reason — write `// detlint: allow({rule}) — <why this site is sound>` on one line"
                    ));
                } else {
                    out.rules.push(rule);
                }
                rest = &t[close + 1..];
                continue;
            }
        }
        out.malformed.push(
            "malformed detlint escape (expected `detlint: allow(Rk) — reason`)".to_string(),
        );
        rest = tail;
    }
    out
}

/// Escapes that apply to code line `l`: its own trailing comment plus
/// the contiguous comment-only block directly above (a blank line or a
/// code line detaches the block).
fn escapes_for_line(lines: &[MaskedLine], l: usize) -> Vec<String> {
    let mut out = parse_escape_comment(&lines[l].comment).rules;
    let mut k = l;
    while k > 0 {
        k -= 1;
        let ml = &lines[k];
        if !ml.code.trim().is_empty() {
            break;
        }
        if ml.comment.trim().is_empty() {
            break;
        }
        out.extend(parse_escape_comment(&ml.comment).rules);
    }
    out
}

// ---------------------------------------------------------------------------
// `#[cfg(test)]` regions — tests are example pins, not linted.
// ---------------------------------------------------------------------------

fn test_regions(lines: &[MaskedLine]) -> Vec<bool> {
    let mut mark = vec![false; lines.len()];
    let mut l = 0usize;
    while l < lines.len() {
        let squish: String = lines[l].code.chars().filter(|c| !c.is_whitespace()).collect();
        if !squish.contains("#[cfg(test)]") {
            l += 1;
            continue;
        }
        // Find the gated item: the next non-blank, non-attribute code line.
        let mut j = l + 1;
        while j < lines.len() {
            let t = lines[j].code.trim();
            if t.is_empty() || t.starts_with("#[") {
                j += 1;
            } else {
                break;
            }
        }
        if j >= lines.len() {
            for m in mark.iter_mut().skip(l) {
                *m = true;
            }
            break;
        }
        let item = lines[j].code.trim_start();
        let is_mod = item.starts_with("mod ")
            || item.starts_with("pub mod ")
            || item.starts_with("pub(crate) mod ");
        if !is_mod {
            // A single gated item (e.g. `#[cfg(test)] use ...`).
            for m in l..=j {
                mark[m] = true;
            }
            l = j + 1;
            continue;
        }
        // Brace-match the module body.
        let mut depth: i64 = 0;
        let mut started = false;
        let mut k = j;
        let mut closed_at: Option<usize> = None;
        'scan: while k < lines.len() {
            for ch in lines[k].code.chars() {
                match ch {
                    '{' => {
                        depth += 1;
                        started = true;
                    }
                    '}' => depth -= 1,
                    _ => {}
                }
                if started && depth == 0 {
                    closed_at = Some(k);
                    break 'scan;
                }
            }
            k += 1;
        }
        match closed_at {
            Some(end) => {
                for m in l..=end {
                    mark[m] = true;
                }
                l = end + 1;
            }
            None => {
                for m in mark.iter_mut().skip(l) {
                    *m = true;
                }
                break;
            }
        }
    }
    mark
}

// ---------------------------------------------------------------------------
// Rule passes. Each emits (0-based line, rule, message) candidates;
// escapes and test regions are resolved centrally in `lint_into`.
// ---------------------------------------------------------------------------

type Candidate = (usize, &'static str, String);

fn binding_name_before(code: &str, p: usize) -> Option<String> {
    let before = &code[..p];
    // `let [mut] name ... Hash...`
    if let Some(lp) = before.rfind("let ") {
        let boundary_ok =
            lp == 0 || !before[..lp].chars().next_back().is_some_and(is_ident_char);
        if boundary_ok {
            let seg = before[lp + 4..].trim_start();
            let seg = seg.strip_prefix("mut ").unwrap_or(seg).trim_start();
            let name: String = seg.chars().take_while(|&c| is_ident_char(c)).collect();
            if !name.is_empty() {
                return Some(name);
            }
        }
    }
    // `name: ...Hash` (struct field / fn param) or `name = Hash...`:
    // scan back for the nearest single `:` or `=`.
    let bytes = before.as_bytes();
    let mut q = before.len();
    while q > 0 {
        q -= 1;
        let b = bytes[q];
        if b == b':' {
            if q > 0 && bytes[q - 1] == b':' {
                q -= 1; // skip over `::`
                continue;
            }
            if q + 1 < bytes.len() && bytes[q + 1] == b':' {
                continue;
            }
        } else if b != b'=' {
            continue;
        }
        let head = before[..q].trim_end();
        let rev: String = head.chars().rev().take_while(|&c| is_ident_char(c)).collect();
        let name: String = rev.chars().rev().collect();
        if !name.is_empty() && name != "mut" && name != "let" {
            return Some(name);
        }
        break;
    }
    None
}

fn for_in_target(code: &str) -> Option<String> {
    if token_positions(code, "for").is_empty() {
        return None;
    }
    let inp = code.find(" in ")?;
    let mut tail = code[inp + 4..].trim_start();
    loop {
        if let Some(t) = tail.strip_prefix('&') {
            tail = t.trim_start();
            continue;
        }
        if let Some(t) = tail.strip_prefix("mut ") {
            tail = t.trim_start();
            continue;
        }
        if let Some(t) = tail.strip_prefix("self.") {
            tail = t;
            continue;
        }
        break;
    }
    let name: String = tail.chars().take_while(|&c| is_ident_char(c)).collect();
    if name.is_empty() {
        None
    } else {
        Some(name)
    }
}

fn r1_hash_iteration(rel: &str, lines: &[MaskedLine], out: &mut Vec<Candidate>) {
    if R1_ALLOWLIST.contains(&rel) {
        return;
    }
    // Hash-like type tokens: the std types plus local aliases of them
    // (two fixpoint sweeps cover alias-of-alias).
    let mut hash_types: Vec<String> = vec!["HashMap".to_string(), "HashSet".to_string()];
    for _ in 0..2 {
        for ml in lines {
            let t = ml.code.trim_start();
            let rest = t
                .strip_prefix("pub type ")
                .or_else(|| t.strip_prefix("pub(crate) type "))
                .or_else(|| t.strip_prefix("type "));
            let Some(rest) = rest else { continue };
            let name: String = rest.chars().take_while(|&c| is_ident_char(c)).collect();
            if name.is_empty() || hash_types.contains(&name) {
                continue;
            }
            if hash_types.iter().any(|h| has_token(&ml.code, h)) {
                hash_types.push(name);
            }
        }
    }
    // Identifiers bound to hash-typed values.
    let mut names: Vec<String> = Vec::new();
    for ml in lines {
        for h in &hash_types {
            for p in token_positions(&ml.code, h) {
                if let Some(n) = binding_name_before(&ml.code, p) {
                    if !names.contains(&n) {
                        names.push(n);
                    }
                }
            }
        }
    }
    if names.is_empty() {
        return;
    }
    for (idx, ml) in lines.iter().enumerate() {
        for name in &names {
            for m in R1_ITER_METHODS {
                let pat = format!("{name}{m}");
                if has_token(&ml.code, &pat) {
                    out.push((idx, "R1", format!(
                        "iteration over hash container `{name}` (`{m}`): hash order is nondeterministic — use a BTreeMap/BTreeSet or a sorted view (allowlist: {})",
                        R1_ALLOWLIST.join(", ")
                    )));
                }
            }
            if for_in_target(&ml.code).as_deref() == Some(name.as_str()) {
                out.push((idx, "R1", format!(
                    "`for` iteration over hash container `{name}`: hash order is nondeterministic — iterate a BTreeMap or sorted keys"
                )));
            }
        }
    }
}

fn r2_wall_clock(rel: &str, lines: &[MaskedLine], out: &mut Vec<Candidate>) {
    if rel.starts_with("runtime/")
        || rel.starts_with("obs/")
        || rel == "bench.rs"
        || rel == "util/logging.rs"
    {
        return;
    }
    for (idx, ml) in lines.iter().enumerate() {
        for tok in ["Instant::now", "SystemTime"] {
            if has_token(&ml.code, tok) {
                out.push((idx, "R2", format!(
                    "wall-clock read (`{tok}`) outside runtime/, obs/, bench.rs, util/logging.rs: route timing through `Runtime` or an `obs` span so it is checkpointable and never feeds a decision"
                )));
            }
        }
    }
}

fn r3_partial_cmp(lines: &[MaskedLine], out: &mut Vec<Candidate>) {
    for (idx, ml) in lines.iter().enumerate() {
        if has_token(&ml.code, "partial_cmp") {
            out.push((idx, "R3", "float comparison via `partial_cmp`: use `total_cmp` — bit-stable total order, no NaN panic/fallback".to_string()));
        }
    }
}

fn r4_rng_sources(rel: &str, lines: &[MaskedLine], out: &mut Vec<Candidate>) {
    if rel == "util/rng.rs" {
        return;
    }
    for (idx, ml) in lines.iter().enumerate() {
        for tok in R4_TOKENS {
            if has_token(&ml.code, tok) {
                out.push((idx, "R4", format!(
                    "nondeterministic randomness source `{tok}`: construct RNGs only through `util::rng` explicitly-seeded streams"
                )));
            }
        }
    }
}

fn r5_file_writes(rel: &str, lines: &[MaskedLine], out: &mut Vec<Candidate>) {
    if rel == "util/fsio.rs" {
        return;
    }
    // Positional pass: a write API is legal while lexically inside the
    // argument list of a `replace_atomic(...)` call (the staging
    // closure writes the tmp sibling). `armed` is true between the
    // `replace_atomic` token and the `(` that must directly follow it;
    // any other non-whitespace character disarms, so a bare import
    // (`use ...::replace_atomic;`) never opens a bogus extent.
    let mut depth: i64 = 0;
    let mut extents: Vec<i64> = Vec::new();
    let mut armed = false;
    for (idx, ml) in lines.iter().enumerate() {
        let code = &ml.code;
        let chars: Vec<(usize, char)> = code.char_indices().collect();
        let mut ci = 0usize;
        while ci < chars.len() {
            let (bp, ch) = chars[ci];
            if starts_token_here(code, bp, "replace_atomic") {
                armed = true;
                ci += "replace_atomic".len();
                continue;
            }
            if extents.is_empty() {
                for tok in R5_TOKENS {
                    if starts_token_here(code, bp, tok) {
                        out.push((idx, "R5", format!(
                            "direct file write (`{tok}`) outside `util::fsio`: stage through `replace_atomic` so preemption never leaves a torn file"
                        )));
                    }
                }
            }
            match ch {
                '(' => {
                    depth += 1;
                    if armed {
                        extents.push(depth);
                        armed = false;
                    }
                }
                ')' => {
                    if extents.last() == Some(&depth) {
                        extents.pop();
                    }
                    depth -= 1;
                }
                c if c.is_whitespace() => {}
                _ => armed = false,
            }
            ci += 1;
        }
    }
}

/// Modules whose outputs are part of the bit-identity contract and must
/// therefore never touch an `obs` wall-clock type (R7).
const R7_PROTECTED: [&str; 2] = ["metrics/", "ckpt/"];

/// Tokens that mark an `obs` wall-clock dependency (R7): module paths
/// and the wall-carrying types they export.
const R7_TOKENS: [&str; 6] =
    ["obs::spans", "obs::wall", "obs::ledger", "SpanGuard", "Stopwatch", "LedgerEntry"];

fn r7_obs_wall(rel: &str, lines: &[MaskedLine], out: &mut Vec<Candidate>) {
    if !R7_PROTECTED.iter().any(|p| rel.starts_with(p)) {
        return;
    }
    for (idx, ml) in lines.iter().enumerate() {
        for tok in R7_TOKENS {
            if has_token(&ml.code, tok) {
                out.push((idx, "R7", format!(
                    "`obs` wall-clock type (`{tok}`) inside a deterministic-output module ({}): span/ledger telemetry belongs at the call site that brackets this code, never in the bytes it produces",
                    R7_PROTECTED.join(", ")
                )));
            }
        }
    }
}

/// True when `tok` occurs in `code` starting exactly at byte `bp`, with
/// an identifier-boundary check on the left edge.
fn starts_token_here(code: &str, bp: usize, tok: &str) -> bool {
    token_positions(&code[bp..], tok).first() == Some(&0)
        && (bp == 0 || !code[..bp].chars().next_back().is_some_and(is_ident_char))
}

fn r6_unsafe_safety(lines: &[MaskedLine], out: &mut Vec<Candidate>) {
    for (idx, ml) in lines.iter().enumerate() {
        if !has_token(&ml.code, "unsafe") {
            continue;
        }
        if ml.comment.contains("SAFETY:") {
            continue;
        }
        let mut k = idx;
        let mut satisfied = false;
        while k > 0 {
            k -= 1;
            let prev = &lines[k];
            let code = prev.code.trim();
            let comment = prev.comment.trim();
            if !code.is_empty() {
                // Consecutive `unsafe impl`s may share one SAFETY block;
                // attributes are transparent.
                if code.starts_with("unsafe impl") || code.starts_with("#[") || code.starts_with("#![") {
                    if comment.contains("SAFETY:") {
                        satisfied = true;
                        break;
                    }
                    continue;
                }
                break;
            }
            if comment.is_empty() {
                break; // blank line detaches the comment block
            }
            if comment.contains("SAFETY:") {
                satisfied = true;
                break;
            }
        }
        if !satisfied {
            out.push((idx, "R6", "`unsafe` without a `// SAFETY:` comment immediately above stating the soundness argument".to_string()));
        }
    }
}

// ---------------------------------------------------------------------------
// Per-file driver.
// ---------------------------------------------------------------------------

fn lint_into(rel: &str, src: &str, rep: &mut Report) {
    let lines = mask_source(src);
    let in_test = test_regions(&lines);

    // Malformed escapes are violations wherever they appear — an escape
    // that fails to parse must never silently suppress anything.
    for (idx, ml) in lines.iter().enumerate() {
        for m in parse_escape_comment(&ml.comment).malformed {
            rep.violations.push(Violation {
                file: rel.to_string(),
                line: idx + 1,
                rule: "escape".to_string(),
                msg: m,
            });
        }
    }

    let mut candidates: Vec<Candidate> = Vec::new();
    r1_hash_iteration(rel, &lines, &mut candidates);
    r2_wall_clock(rel, &lines, &mut candidates);
    r3_partial_cmp(&lines, &mut candidates);
    r4_rng_sources(rel, &lines, &mut candidates);
    r5_file_writes(rel, &lines, &mut candidates);
    r6_unsafe_safety(&lines, &mut candidates);
    r7_obs_wall(rel, &lines, &mut candidates);

    candidates.sort_by(|a, b| (a.0, a.1).cmp(&(b.0, b.1)));
    candidates.dedup_by(|a, b| a.0 == b.0 && a.1 == b.1);

    for (l, rule, msg) in candidates {
        if in_test.get(l).copied().unwrap_or(false) {
            continue;
        }
        if escapes_for_line(&lines, l).iter().any(|r| r == rule) {
            *rep.escapes_used.entry(rule.to_string()).or_insert(0) += 1;
        } else {
            rep.violations.push(Violation {
                file: rel.to_string(),
                line: l + 1,
                rule: rule.to_string(),
                msg,
            });
        }
    }
    rep.violations
        .sort_by(|a, b| (&a.file, a.line, &a.rule).cmp(&(&b.file, b.line, &b.rule)));
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn masking_separates_comments_and_blanks_strings() {
        let src = "let x = \"Instant::now\"; // Instant::now here is comment\n";
        let lines = mask_source(src);
        assert!(!has_token(&lines[0].code, "Instant::now"));
        assert!(lines[0].comment.contains("Instant::now"));
    }

    #[test]
    fn lifetimes_do_not_eat_code() {
        let src = "fn f<'a>(x: &'a str) -> &'a str { x }\n";
        let lines = mask_source(src);
        assert!(lines[0].code.contains("str"));
    }

    #[test]
    fn char_literals_are_blanked() {
        let src = "let c = 'x'; let d = '\\n'; let e = b'\"';\n";
        let lines = mask_source(src);
        assert!(!lines[0].code.contains('x') || lines[0].code.contains("let"));
        assert!(!lines[0].code.contains('"'));
    }

    #[test]
    fn raw_strings_are_blanked() {
        let src = "let s = r#\"SystemTime \"quoted\" inside\"#; let t = 1;\n";
        let lines = mask_source(src);
        assert!(!has_token(&lines[0].code, "SystemTime"));
        assert!(lines[0].code.contains("let t = 1;"));
    }

    #[test]
    fn escape_reason_required() {
        let scan = parse_escape_comment("// detlint: allow(R3) —");
        assert!(scan.rules.is_empty());
        assert_eq!(scan.malformed.len(), 1);
        let ok = parse_escape_comment("// detlint: allow(R3) — callers guarantee non-NaN");
        assert_eq!(ok.rules, vec!["R3".to_string()]);
        assert!(ok.malformed.is_empty());
    }

    #[test]
    fn unknown_rule_is_malformed() {
        let scan = parse_escape_comment("// detlint: allow(R9) — whatever");
        assert!(scan.rules.is_empty());
        assert_eq!(scan.malformed.len(), 1);
    }

    #[test]
    fn replace_atomic_extent_suppresses_r5() {
        let src = "use crate::util::fsio::replace_atomic;\npub fn save(p: &std::path::Path) -> std::io::Result<()> {\n    replace_atomic(p, |tmp| {\n        let f = std::fs::File::create(tmp)?;\n        drop(f);\n        Ok(())\n    })\n}\n";
        let rep = lint_source_str("x.rs", src);
        assert!(rep.violations.is_empty(), "{:?}", rep.violations);
    }

    #[test]
    fn grouped_unsafe_impls_share_one_safety_comment() {
        let src = "// SAFETY: all interior mutability is atomic.\nunsafe impl Send for T {}\nunsafe impl Sync for T {}\n";
        let rep = lint_source_str("x.rs", src);
        assert!(rep.violations.is_empty(), "{:?}", rep.violations);
    }
}
