//! `xtask` CLI. `xtask detlint [--root PATH]` runs the determinism &
//! safety audit over a source tree and exits nonzero on any violation.

use std::path::PathBuf;
use std::process::ExitCode;

use xtask::detlint;

fn usage() -> &'static str {
    "usage: xtask detlint [--root PATH]\n\n\
     Runs the determinism & safety audit (rules R1-R7, see\n\
     docs/DETERMINISM.md) over PATH (default: rust/src, falling back\n\
     to src). Exits 1 if any violation is found."
}

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    match args.first().map(String::as_str) {
        Some("detlint") => run_detlint(&args[1..]),
        Some("--help") | Some("-h") | None => {
            println!("{}", usage());
            ExitCode::SUCCESS
        }
        Some(other) => {
            eprintln!("xtask: unknown task `{other}`\n\n{}", usage());
            ExitCode::from(2)
        }
    }
}

fn run_detlint(args: &[String]) -> ExitCode {
    let mut root: Option<PathBuf> = None;
    let mut i = 0usize;
    while i < args.len() {
        match args[i].as_str() {
            "--root" => {
                let Some(v) = args.get(i + 1) else {
                    eprintln!("xtask: --root needs a value\n\n{}", usage());
                    return ExitCode::from(2);
                };
                root = Some(PathBuf::from(v));
                i += 2;
            }
            "--help" | "-h" => {
                println!("{}", usage());
                return ExitCode::SUCCESS;
            }
            other => {
                eprintln!("xtask: unknown detlint argument `{other}`\n\n{}", usage());
                return ExitCode::from(2);
            }
        }
    }
    let root = root.unwrap_or_else(|| {
        let preferred = PathBuf::from("rust/src");
        if preferred.is_dir() {
            preferred
        } else {
            PathBuf::from("src")
        }
    });
    if !root.exists() {
        eprintln!("xtask: detlint root `{}` does not exist", root.display());
        return ExitCode::from(2);
    }
    match detlint::lint_root(&root) {
        Ok(rep) => {
            for v in &rep.violations {
                println!("detlint: {}:{}: [{}] {}", v.file, v.line, v.rule, v.msg);
            }
            println!("{}", rep.summary_line());
            if rep.violations.is_empty() {
                ExitCode::SUCCESS
            } else {
                ExitCode::FAILURE
            }
        }
        Err(e) => {
            eprintln!("xtask: detlint failed to read `{}`: {e}", root.display());
            ExitCode::from(2)
        }
    }
}
