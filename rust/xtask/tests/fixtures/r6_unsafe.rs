//! Fixture: triggers R6 exactly once — unsafe without a SAFETY comment.

/// Reads the first byte of a non-empty slice without an argument.
pub fn first_byte(v: &[f64]) -> u8 {
    unsafe { *(v.as_ptr() as *const u8) }
}
