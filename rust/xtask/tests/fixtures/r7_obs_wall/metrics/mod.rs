//! Fixture: triggers R7 exactly once — an `obs` wall-clock type leaking
//! into a deterministic-output module (`metrics/`). Both tokens sit on
//! one line, so the per-(line, rule) dedup still yields one finding.

use crate::obs::spans::SpanGuard;

/// The import above is the leak; the body never needs to mention it for
/// the rule to fire.
pub fn serialize_timed() {}
