//! Fixture: an allow escape with an empty reason must NOT suppress the
//! finding, and the malformed escape is itself reported.

/// Sorts with partial_cmp under a reasonless escape: both are flagged.
pub fn sort_samples(v: &mut [f64]) {
    // detlint: allow(R3) —
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
}
