//! Fixture: triggers R2 exactly once — wall-clock read outside runtime/.

/// Times a closure with an ambient clock instead of the Runtime.
pub fn timed<F: FnOnce()>(f: F) -> u128 {
    let t0 = std::time::Instant::now();
    f();
    t0.elapsed().as_nanos()
}
