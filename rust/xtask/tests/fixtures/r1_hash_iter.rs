//! Fixture: triggers R1 exactly once — iteration over a HashMap.

use std::collections::HashMap;

/// Sums the values of `m` in hash order: nondeterministic fold.
pub fn sum_values(m: &HashMap<u64, f64>) -> f64 {
    m.values().sum()
}
