//! Fixture: triggers R5 exactly once — direct file write.

use std::io::Write;

/// Writes bytes straight to `path`: preemption leaves a torn file.
pub fn dump(path: &std::path::Path, bytes: &[u8]) -> std::io::Result<()> {
    let mut f = std::fs::File::create(path)?;
    f.write_all(bytes)
}
