//! Fixture: passes every rule. Ordered containers, total_cmp, a
//! SAFETY-commented unsafe, and a #[cfg(test)] module that is free to
//! compare however it likes (tests are skipped by detlint).

use std::collections::BTreeMap;

/// Sums values in key order: deterministic fold.
pub fn sum_values(m: &BTreeMap<u64, f64>) -> f64 {
    m.values().sum()
}

/// Sorts samples under the IEEE-754 total order.
pub fn sort_samples(v: &mut [f64]) {
    v.sort_by(|a, b| a.total_cmp(b));
}

/// Reads the first byte of a slice the caller promises is non-empty.
pub fn first_byte(v: &[f64]) -> u8 {
    debug_assert!(!v.is_empty());
    // SAFETY: the pointer is valid for at least one f64 (asserted
    // above), and any initialized byte is a valid u8.
    unsafe { *(v.as_ptr() as *const u8) }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tests_may_use_partial_cmp() {
        let mut v = vec![2.0_f64, 1.0];
        v.sort_by(|a, b| a.partial_cmp(b).unwrap());
        assert_eq!(v, vec![1.0, 2.0]);
    }
}
