//! Fixture: triggers R4 exactly once — ambient RNG construction.

/// Draws from an OS-entropy-seeded generator: unreproducible.
pub fn ambient_draw() -> u64 {
    let mut rng = rand::thread_rng();
    rng.gen()
}
