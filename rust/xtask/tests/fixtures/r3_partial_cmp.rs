//! Fixture: triggers R3 exactly once — float sort via partial_cmp.

/// Sorts samples with a NaN-panicking partial order.
pub fn sort_samples(v: &mut Vec<f64>) {
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
}
