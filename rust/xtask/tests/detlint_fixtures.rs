//! Fixture battery for `detlint`: each rule R1-R7 fires exactly once on
//! its fixture, the clean fixture is silent, reasonless escapes are
//! rejected, and the CLI exit codes match (acceptance criteria of the
//! determinism-audit issue).

use std::path::{Path, PathBuf};
use std::process::Command;

use xtask::detlint::{lint_root, lint_source_str};

fn fixture(name: &str) -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("tests/fixtures").join(name)
}

fn assert_single_violation(name: &str, rule: &str) {
    let rep = lint_root(&fixture(name)).expect("fixture readable");
    assert_eq!(
        rep.violations.len(),
        1,
        "{name}: expected exactly one violation, got {:?}",
        rep.violations
    );
    assert_eq!(rep.violations[0].rule, rule, "{name}: {:?}", rep.violations);
}

#[test]
fn r1_hash_iteration_fires_once() {
    assert_single_violation("r1_hash_iter.rs", "R1");
}

#[test]
fn r2_wall_clock_fires_once() {
    assert_single_violation("r2_wallclock.rs", "R2");
}

#[test]
fn r3_partial_cmp_fires_once() {
    assert_single_violation("r3_partial_cmp.rs", "R3");
}

#[test]
fn r4_ambient_rng_fires_once() {
    assert_single_violation("r4_rng.rs", "R4");
}

#[test]
fn r5_direct_write_fires_once() {
    assert_single_violation("r5_file_write.rs", "R5");
}

#[test]
fn r6_missing_safety_fires_once() {
    assert_single_violation("r6_unsafe.rs", "R6");
}

#[test]
fn r7_obs_wall_fires_once() {
    // A directory fixture, not a single file: R7's predicate matches on
    // the path relative to the lint root (`metrics/...`), which a bare
    // file name can never satisfy.
    assert_single_violation("r7_obs_wall", "R7");
}

#[test]
fn clean_fixture_is_silent() {
    let rep = lint_root(&fixture("clean.rs")).expect("fixture readable");
    assert!(rep.violations.is_empty(), "{:?}", rep.violations);
    assert!(rep.escapes_used.is_empty(), "{:?}", rep.escapes_used);
}

#[test]
fn allow_escape_requires_nonempty_reason() {
    let rep = lint_root(&fixture("allow_no_reason.rs")).expect("fixture readable");
    let rules: Vec<&str> = rep.violations.iter().map(|v| v.rule.as_str()).collect();
    assert!(
        rules.contains(&"escape"),
        "the malformed escape itself must be reported: {:?}",
        rep.violations
    );
    assert!(
        rules.contains(&"R3"),
        "a reasonless escape must not suppress the finding: {:?}",
        rep.violations
    );
}

#[test]
fn valid_escape_suppresses_and_is_counted() {
    let src = "\
/// Sorts with a documented exception.\n\
pub fn sort_samples(v: &mut [f64]) {\n\
    // detlint: allow(R3) — inputs are clamped upstream, NaN impossible\n\
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());\n\
}\n";
    let rep = lint_source_str("escaped.rs", src);
    assert!(rep.violations.is_empty(), "{:?}", rep.violations);
    assert_eq!(rep.escapes_used.get("R3"), Some(&1));
}

#[test]
fn summary_line_reports_all_rules() {
    let rep = lint_source_str("empty.rs", "");
    let line = rep.summary_line();
    for r in ["R1", "R2", "R3", "R4", "R5", "R6", "R7"] {
        assert!(line.contains(&format!("{r}=0")), "{line}");
    }
}

#[test]
fn cli_exits_nonzero_on_violations_and_zero_on_clean() {
    let bin = env!("CARGO_BIN_EXE_xtask");
    for (name, expect_ok) in [
        ("r1_hash_iter.rs", false),
        ("r2_wallclock.rs", false),
        ("r3_partial_cmp.rs", false),
        ("r4_rng.rs", false),
        ("r5_file_write.rs", false),
        ("r6_unsafe.rs", false),
        ("r7_obs_wall", false),
        ("allow_no_reason.rs", false),
        ("clean.rs", true),
    ] {
        let out = Command::new(bin)
            .args(["detlint", "--root"])
            .arg(fixture(name))
            .output()
            .expect("xtask binary runs");
        assert_eq!(
            out.status.success(),
            expect_ok,
            "{name}: status {:?}\nstdout: {}",
            out.status,
            String::from_utf8_lossy(&out.stdout)
        );
    }
}
