//! PJRT runtime benchmarks: the AOT-compiled train_step / quantize /
//! eval executions that dominate round wall-clock. Requires
//! `make artifacts`; exits cleanly (with a note) if they're absent.

use qccf::bench::BenchSet;
use qccf::runtime::{artifacts_dir, Runtime};
use qccf::util::rng::Rng;
use qccf::util::threadpool;

fn main() {
    if !artifacts_dir().join("manifest.json").exists() {
        println!("bench_runtime: artifacts not built (run `make artifacts`); skipping");
        return;
    }
    for profile in ["tiny", "small"] {
        let Ok(rt) = Runtime::load(&artifacts_dir(), profile) else {
            println!("bench_runtime: profile `{profile}` unavailable; skipping");
            continue;
        };
        let info = rt.info.clone_info();
        let mut rng = Rng::seed_from(5);
        let theta = rt.init().expect("init");
        let pix = info.pix;
        let xs: Vec<f32> =
            (0..info.tau * info.batch * pix).map(|_| rng.gaussian(0.0, 1.0) as f32).collect();
        let ys: Vec<i32> =
            (0..info.tau * info.batch).map(|_| rng.below(info.classes) as i32).collect();
        let mut noise = vec![0.0f32; info.z];
        rng.fill_uniform_f32(&mut noise);
        let ex: Vec<f32> = (0..info.eval_batch * pix).map(|_| rng.gaussian(0.0, 1.0) as f32).collect();
        let ey: Vec<i32> = (0..info.eval_batch).map(|_| rng.below(info.classes) as i32).collect();
        let ew = vec![1.0f32; info.eval_batch];

        let mut set = BenchSet::new(&format!("runtime_{profile}"));
        set.bench("train_step_tau6", || rt.train_step(&theta, &xs, &ys, 0.05).unwrap().mean_loss);
        set.bench("quantize_q8", || rt.quantize(&theta, &noise, 8.0).unwrap().1);
        set.bench("eval_chunk", || rt.eval_chunk(&theta, &ex, &ey, &ew).unwrap().1);

        // Parallel-vs-serial round fan-out: 8 simulated clients (fixed
        // seeds) through the engine's worker pool. The jsonl pair
        // tracks the staged-engine speedup from this PR on — expect
        // parity on a 1-core CI box, ~min(8, cores−1)× elsewhere.
        let clients: Vec<(Vec<f32>, Vec<i32>)> = (0..8u64)
            .map(|k| {
                let mut crng = Rng::seed_from(1000 + k);
                let cxs: Vec<f32> = (0..info.tau * info.batch * pix)
                    .map(|_| crng.gaussian(0.0, 1.0) as f32)
                    .collect();
                let cys: Vec<i32> =
                    (0..info.tau * info.batch).map(|_| crng.below(info.classes) as i32).collect();
                (cxs, cys)
            })
            .collect();
        for (name, threads) in
            [("round8_serial", 1), ("round8_parallel", threadpool::default_threads())]
        {
            set.bench(name, || {
                threadpool::parallel_map(&clients, threads, |_, (cxs, cys)| {
                    rt.train_step(&theta, cxs, cys, 0.05).unwrap().mean_loss
                })
            });
        }
        set.finish();
    }
}

/// Tiny helper mirroring the fields bench needs (keeps the bench free of
/// borrow gymnastics against `rt.info`).
trait CloneInfo {
    fn clone_info(&self) -> Info;
}

struct Info {
    z: usize,
    tau: usize,
    batch: usize,
    eval_batch: usize,
    classes: usize,
    pix: usize,
}

impl CloneInfo for qccf::runtime::ProfileInfo {
    fn clone_info(&self) -> Info {
        Info {
            z: self.z,
            tau: self.tau,
            batch: self.batch,
            eval_batch: self.eval_batch,
            classes: self.classes,
            pix: self.pix(),
        }
    }
}
