//! Micro-benchmarks of the closed-form KKT solver (P3.2″) — the inner
//! loop of every GA fitness evaluation, so the hottest pure-Rust path in
//! the round decision.

use qccf::bench::BenchSet;
use qccf::config::SystemParams;
use qccf::solver::{self, Case5Mode, ClientCtx};
use qccf::util::rng::Rng;

fn main() {
    let p = SystemParams::femnist_small();
    let mut rng = Rng::seed_from(42);
    let cases: Vec<(f64, ClientCtx)> = (0..256)
        .map(|_| {
            let lambda2 = p.eps2 + 10f64.powf(rng.range(-2.0, 3.0));
            let ctx = ClientCtx {
                d_i: rng.range(300.0, 2500.0),
                w_round: rng.range(0.02, 0.5),
                rate: rng.range(8e6, 40e6),
                theta_max: rng.range(0.05, 2.0),
                q_prev: rng.range(1.0, 14.0),
            };
            (lambda2, ctx)
        })
        .collect();

    let mut set = BenchSet::new("solver");
    let mut i = 0usize;
    set.bench("closed_form_taylor", || {
        i = (i + 1) % cases.len();
        let (l2, ctx) = &cases[i];
        solver::solve_client(&p, *l2, ctx, Case5Mode::Taylor)
    });
    let mut i = 0usize;
    set.bench("closed_form_bisect", || {
        i = (i + 1) % cases.len();
        let (l2, ctx) = &cases[i];
        solver::solve_client(&p, *l2, ctx, Case5Mode::Bisect)
    });
    let mut i = 0usize;
    set.bench("brute_force_scan", || {
        i = (i + 1) % cases.len();
        let (l2, ctx) = &cases[i];
        solver::solve_brute(&p, *l2, ctx)
    });
    let mut i = 0usize;
    set.bench("cubic_root", || {
        i = (i + 1) % cases.len();
        qccf::solver::cubic::positive_root(0.1 + i as f64)
    });
    set.finish();
}
