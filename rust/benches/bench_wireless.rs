//! Wireless-substrate benchmarks: per-round channel draws (U×C Rician
//! samples + Shannon rates) and the energy/latency model evaluations.

use qccf::bench::BenchSet;
use qccf::config::SystemParams;
use qccf::energy;
use qccf::util::rng::Rng;
use qccf::wireless::{channel_rate, ChannelModel};

fn main() {
    let params = SystemParams::femnist_small();
    let mut rng = Rng::seed_from(17);
    let model = ChannelModel::new(&params, &mut rng);

    let mut set = BenchSet::new("wireless");
    {
        let mut r = Rng::seed_from(19);
        let m = model.clone();
        set.bench("channel_draw_10x10", move || m.draw(&mut r).rate(0, 0));
    }
    {
        let mut r = Rng::seed_from(23);
        set.bench("rician_power_sample", move || r.rician_power(4.0, 1.0));
    }
    set.bench("shannon_rate", || channel_rate(1e6, 0.2, 1e-8, 4e-21));
    set.bench("energy_model_full_client", || {
        let f = 6e8;
        energy::client_energy(&params, 1200.0, f, 8, 20e6)
            + energy::client_latency(&params, 1200.0, f, 8, 20e6)
    });
    set.bench("s_of_q", || energy::s_of_q(&params, 1200.0, 8, 20e6));
    set.finish();
}
