//! GA benchmarks: the combinatorial half of the round decision (P3.1)
//! with the real QCCF fitness (inner solver per candidate) — the paper's
//! Algorithm 1 end to end — plus a per-fitness micro-bench.

use qccf::bench::BenchSet;
use qccf::config::SystemParams;
use qccf::ga::{self, Chromosome, GaParams};
use qccf::lyapunov::Queues;
use qccf::sched::{evaluate_allocation, RoundInputs};
use qccf::solver::Case5Mode;
use qccf::util::rng::Rng;
use qccf::wireless::ChannelModel;

fn main() {
    let params = SystemParams::femnist_small();
    let mut rng = Rng::seed_from(3);
    let model = ChannelModel::new(&params, &mut rng);
    let channels = model.draw(&mut rng);
    let sizes: Vec<f64> =
        (0..params.num_clients).map(|_| rng.gaussian(1200.0, 150.0).max(64.0)).collect();
    let total: f64 = sizes.iter().sum();
    let w_full: Vec<f64> = sizes.iter().map(|d| d / total).collect();
    let mut queues = Queues::new();
    queues.update(&params, params.eps1 + 30.0, params.eps2 + 1.0);
    let g2 = vec![2.0; 10];
    let sigma2 = vec![0.5; 10];
    let theta_max = vec![0.4; 10];
    let q_prev = vec![6.0; 10];
    let inputs = RoundInputs {
        params: &params,
        round: 5,
        channels: &channels,
        sizes: &sizes,
        w_full: &w_full,
        g2: &g2,
        sigma2: &sigma2,
        theta_max: &theta_max,
        q_prev: &q_prev,
        queues: &queues,
        avail: None,
    };

    let mut set = BenchSet::new("ga");
    {
        let mut r = Rng::seed_from(7);
        set.bench("fitness_eval_one_chromosome", || {
            let c = Chromosome::random(10, 10, &mut r);
            evaluate_allocation(&inputs, &c, Case5Mode::Taylor).0
        });
    }
    {
        // Same workload through the cached evaluation subsystem
        // (per-round EvalCtx + exact-key solve memo + reusable
        // scratch) — bit-identical J0s, decision-stage hot-path cost.
        let ctx = qccf::sched::EvalCtx::new(&inputs, Case5Mode::Taylor);
        let mut scratch = ctx.make_scratch();
        let mut r = Rng::seed_from(7);
        set.bench("fitness_eval_ctx_memo", || {
            let c = Chromosome::random(10, 10, &mut r);
            ctx.evaluate_j0(&c, &mut scratch)
        });
    }
    {
        // Full Algorithm 1 on the cached path (EvalCtx + scratch + GA
        // fitness cache) — what QccfScheduler::decide actually runs.
        let ctx = qccf::sched::EvalCtx::new(&inputs, Case5Mode::Taylor);
        let mut scratches = vec![ctx.make_scratch()];
        let mut r = Rng::seed_from(11);
        set.bench("algorithm1_full_run_cached", || {
            ga::optimize_scratch(10, 10, &GaParams::default(), &mut r, &[], &mut scratches, |c, s| {
                ctx.evaluate_j0(c, s)
            })
            .best_j0
        });
    }
    {
        let mut r = Rng::seed_from(11);
        set.bench("algorithm1_full_run_default", || {
            ga::optimize(10, 10, &GaParams::default(), &mut r, |c| {
                evaluate_allocation(&inputs, c, Case5Mode::Taylor).0
            })
            .best_j0
        });
    }
    {
        let small = GaParams { population: 12, generations: 8, ..GaParams::default() };
        let mut r = Rng::seed_from(13);
        set.bench("algorithm1_small_budget", || {
            ga::optimize(10, 10, &small, &mut r, |c| {
                evaluate_allocation(&inputs, c, Case5Mode::Taylor).0
            })
            .best_j0
        });
    }
    {
        // Parallel fitness fan-out (same trajectory, different wall
        // clock — see GaParams::threads).
        let par = GaParams {
            threads: qccf::util::threadpool::default_threads(),
            ..GaParams::default()
        };
        let mut r = Rng::seed_from(11);
        set.bench("algorithm1_full_run_parallel", || {
            ga::optimize(10, 10, &par, &mut r, |c| {
                evaluate_allocation(&inputs, c, Case5Mode::Taylor).0
            })
            .best_j0
        });
    }
    set.finish();
}
