//! Quantizer benchmarks: the Rust mirror (pure CPU), the wire codec
//! (encode/decode at eq. (5) densities), across model sizes.

use qccf::bench::BenchSet;
use qccf::quant;
use qccf::util::rng::Rng;

fn main() {
    let mut set = BenchSet::new("quant");
    for &z in &[1242usize, 20_522, 246_590] {
        let mut rng = Rng::seed_from(z as u64);
        let theta: Vec<f32> = (0..z).map(|_| rng.gaussian(0.0, 0.5) as f32).collect();
        let mut noise = vec![0.0f32; z];
        rng.fill_uniform_f32(&mut noise);

        set.bench(&format!("stochastic_quantize_z{z}_q8"), || {
            quant::stochastic_quantize(&theta, &noise, 8.0)
        });

        let (idx, signs, tmax) = quant::knot_indices(&theta, &noise, 8);
        set.bench(&format!("wire_encode_z{z}_q8"), || {
            quant::encode(tmax, &signs, &idx, 8)
        });
        let bytes = quant::encode(tmax, &signs, &idx, 8);
        set.bench(&format!("wire_decode_z{z}_q8"), || quant::decode(&bytes, z, 8).unwrap());
        // The transport hot path: fold w·(idx·Δ) straight out of the
        // bitstream (no dequantized Vec<f32> materialized).
        let mut acc = vec![0.0f32; z];
        set.bench(&format!("wire_decode_fold_z{z}_q8"), || {
            quant::wire::fold_into(&mut acc, 0.25, &bytes, 8).unwrap()
        });
    }
    // Noise-stream generation (runs once per upload on the hot path).
    {
        let mut rng = Rng::seed_from(99);
        let mut buf = vec![0.0f32; 20_522];
        set.bench("noise_fill_z20522", move || {
            rng.fill_uniform_f32(&mut buf);
            buf[0]
        });
    }
    set.finish();
}
