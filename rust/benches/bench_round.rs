//! End-to-end round-decision benchmark: one full scheduler decision per
//! algorithm on a fresh channel draw — the paper's per-round control
//! overhead (Table-less, but the practical cost of Algorithm 1 + KKT).

use qccf::baselines::{make_scheduler, ALL_ALGORITHMS};
use qccf::bench::BenchSet;
use qccf::config::SystemParams;
use qccf::lyapunov::Queues;
use qccf::sched::RoundInputs;
use qccf::util::rng::Rng;
use qccf::wireless::ChannelModel;

fn main() {
    let params = SystemParams::femnist_small();
    let mut rng = Rng::seed_from(29);
    let model = ChannelModel::new(&params, &mut rng);
    let channels = model.draw(&mut rng);
    let sizes: Vec<f64> =
        (0..params.num_clients).map(|_| rng.gaussian(1200.0, 150.0).max(64.0)).collect();
    let total: f64 = sizes.iter().sum();
    let w_full: Vec<f64> = sizes.iter().map(|d| d / total).collect();
    let mut queues = Queues::new();
    queues.update(&params, params.eps1 + 30.0, params.eps2 + 1.0);
    let g2 = vec![2.0; 10];
    let sigma2 = vec![0.5; 10];
    let theta_max = vec![0.4; 10];
    let q_prev = vec![6.0; 10];
    let inputs = RoundInputs {
        params: &params,
        round: 5,
        channels: &channels,
        sizes: &sizes,
        w_full: &w_full,
        g2: &g2,
        sigma2: &sigma2,
        theta_max: &theta_max,
        q_prev: &q_prev,
        queues: &queues,
        avail: None,
    };

    let mut set = BenchSet::new("round_decision");
    for alg in ALL_ALGORITHMS {
        let mut sched = make_scheduler(alg, 1).unwrap();
        set.bench(alg, || sched.decide(&inputs).assignments.len());
    }
    set.finish();
}
