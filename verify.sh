#!/usr/bin/env bash
# Tier-1 gate in one command (see ROADMAP.md):
#
#   ./verify.sh
#
# Runs the release build, the detlint determinism & safety audit
# (docs/DETERMINISM.md), the full test suite, the Miri UB gate when a
# nightly toolchain is present, and clippy with warnings denied, from
# wherever the Cargo manifest lives relative to this repo.
set -euo pipefail
cd "$(dirname "$0")"

# The crate roots at the repo top level (rust/src via the manifest); fall
# back to rust/ if a standalone manifest is ever introduced there. The
# authoring container has no cargo toolchain — this gate is for the CI /
# toolchain image that carries the manifest and the vendored xla crate.
if [ -f Cargo.toml ]; then
    :
elif [ -f rust/Cargo.toml ]; then
    cd rust
else
    echo "verify.sh: no Cargo.toml found at repo root or rust/" >&2
    echo "verify.sh: run from the toolchain image (see ROADMAP.md tier-1)" >&2
    exit 1
fi

echo "== cargo build --release =="
cargo build --release

# Determinism & safety audit (rules R1-R7, docs/DETERMINISM.md): a hard
# gate before anything else runs, so a stray HashMap iteration or
# partial_cmp never reaches the (much slower) test stage. The xtask
# crate is a standalone zero-dependency workspace, invoked by manifest
# path so it builds the same whether we cd'd into rust/ or not. Its
# summary line includes the allow-escape count per rule — watch that
# number in CI logs for drift.
XTASK_DIR="rust/xtask"; [ -d "$XTASK_DIR" ] || XTASK_DIR="xtask"
DETLINT_ROOT="rust/src"; [ -d "$DETLINT_ROOT" ] || DETLINT_ROOT="src"
echo "== detlint (determinism & safety audit over $DETLINT_ROOT) =="
cargo run --release --quiet --manifest-path "$XTASK_DIR/Cargo.toml" \
    -p xtask -- detlint --root "$DETLINT_ROOT"
echo "== xtask self-test (detlint fixture battery) =="
cargo test -q --manifest-path "$XTASK_DIR/Cargo.toml" -p xtask

echo "== cargo test -q =="
cargo test -q

# Miri UB gate: interpret the `miri_`-prefixed unit-test subset — the
# ExecClock atomics behind `unsafe impl Send/Sync for Runtime` and the
# ckpt codec's byte-slice arithmetic — under nightly Miri. Skip with a
# warning when no nightly toolchain is installed (the default CI image
# is stable-only).
if cargo +nightly miri --version >/dev/null 2>&1; then
    echo "== cargo +nightly miri test --lib miri_ =="
    cargo +nightly miri test --lib miri_
else
    echo "verify.sh: WARNING — nightly miri unavailable; skipping the UB gate" >&2
fi

# clippy::unwrap_used is denied per-module (inner attrs in fl/mod.rs,
# sched/mod.rs, ckpt/mod.rs) rather than on this command line, so the
# ban scopes to the crash-path-critical subsystems while tests and
# benches stay free to unwrap.
echo "== cargo clippy --all-targets --release -- -D warnings =="
cargo clippy --all-targets --release -- -D warnings
echo "== cargo doc --no-deps (warnings denied) =="
RUSTDOCFLAGS="${RUSTDOCFLAGS:-} -D warnings" cargo doc --no-deps --quiet

# Wire-transport perf baseline: quick encode / decode-fold smoke at
# Z = 20k, q ∈ {4, 8} (pure Rust, no artifacts). Writes BENCH_wire.json
# so subsequent PRs have ns/elem numbers to regress against.
echo "== bench-wire smoke (target/BENCH_wire.json) =="
QCCF_BENCH_WARMUP_MS=20 QCCF_BENCH_MEASURE_MS=100 \
    cargo run --release --quiet -- bench-wire \
    --z 20000 --qs 4,8 --out target/BENCH_wire.json
[ -s target/BENCH_wire.json ] || {
    echo "verify.sh: bench-wire wrote no target/BENCH_wire.json" >&2
    exit 1
}

# Decision-stage perf baseline: quick J0-evaluation smoke at U ∈
# {100, 1000}, C = U/2, cached (EvalCtx + solve memo + scratch) vs the
# uncached reference, plus the classed-vs-exact rows at U ∈ {1000,
# 10000, 100000} — class-level throughput, approximation gap, and the
# stress-100k decision round all in one pass (pure Rust, no artifacts).
echo "== bench-sched smoke (target/BENCH_sched.json) =="
QCCF_BENCH_WARMUP_MS=20 QCCF_BENCH_MEASURE_MS=100 \
    cargo run --release --quiet -- bench-sched \
    --us 100,1000 --pool 16 --class-us 1000,10000,100000 \
    --out target/BENCH_sched.json
[ -s target/BENCH_sched.json ] || {
    echo "verify.sh: bench-sched wrote no target/BENCH_sched.json" >&2
    exit 1
}

# Snapshot-codec perf baseline: quick encode/decode smoke over a
# synthetic mid-horizon snapshot at Z = 20k, U ∈ {100, 1000} (pure
# Rust, no artifacts). Writes BENCH_ckpt.json so subsequent PRs have
# MB/s + snapshot-bytes numbers to regress against.
echo "== bench-ckpt smoke (target/BENCH_ckpt.json) =="
QCCF_BENCH_WARMUP_MS=20 QCCF_BENCH_MEASURE_MS=100 \
    cargo run --release --quiet -- bench-ckpt \
    --z 20000 --us 100,1000 --out target/BENCH_ckpt.json
[ -s target/BENCH_ckpt.json ] || {
    echo "verify.sh: bench-ckpt wrote no target/BENCH_ckpt.json" >&2
    exit 1
}

# Advisory perf diff, then refresh the committed baselines: compare the
# fresh target/BENCH_*.json against the copies committed at the repo
# root and warn (never fail — micro-bench noise) when a metric
# regressed more than 20%. Only after the diff are all three baselines
# copied to the root, so the log shows regressions against what was
# actually committed.
echo "== bench-diff (fresh target/ vs committed baselines) =="
cargo run --release --quiet -- bench-diff --fresh target --baseline .
for b in BENCH_wire.json BENCH_sched.json BENCH_ckpt.json; do
    cp "target/$b" "$b"
done

# Scenario-path smoke: three built-in scenarios through the sweep
# runner (2 rounds, tiny profile) — churn-100 exercises the
# availability layer (masked decide, mid-round departures, the
# departed column) end to end. Needs artifacts, like the integration
# tests.
if [ -f artifacts/manifest.json ]; then
    echo "== sweep --quick smoke (paper-femnist, zipf-skew, churn-100) =="
    SWEEP_OUT="$(mktemp -d)"
    trap 'rm -rf "$SWEEP_OUT"' EXIT
    cargo run --release --quiet -- sweep \
        --scenarios paper-femnist,zipf-skew,churn-100 --algorithms qccf \
        --seeds 1 --quick --profile tiny --threads 2 --out "$SWEEP_OUT"
    for f in "$SWEEP_OUT"/paper-femnist__qccf__seed1.jsonl \
             "$SWEEP_OUT"/zipf-skew__qccf__seed1.jsonl \
             "$SWEEP_OUT"/churn-100__qccf__seed1.jsonl \
             "$SWEEP_OUT"/summary.csv; do
        [ -s "$f" ] || { echo "verify.sh: sweep smoke missing $f" >&2; exit 1; }
    done
    # Resume path: re-running over the same --out must skip every
    # completed triple (0 to run) and still rewrite a complete summary.
    echo "== sweep --resume smoke (same --out, all triples skipped) =="
    cargo run --release --quiet -- sweep \
        --scenarios paper-femnist,zipf-skew,churn-100 --algorithms qccf \
        --seeds 1 --quick --profile tiny --threads 2 --out "$SWEEP_OUT" --resume
    [ -s "$SWEEP_OUT"/summary.csv ] || {
        echo "verify.sh: sweep --resume lost summary.csv" >&2
        exit 1
    }
    # Report smoke: aggregate the sweep directory just produced. The
    # report reads only summary.csv + ledger.jsonl + sketch sidecars
    # (never the per-round JSONL traces), and must print every section
    # header even on a tiny run.
    echo "== report smoke (aggregate \$SWEEP_OUT) =="
    REPORT_OUT="$(cargo run --release --quiet -- report --dir "$SWEEP_OUT")"
    for section in "== qccf report ==" "-- outcomes --" "-- stage times" \
                   "-- energy quantiles" "-- bench deltas --"; do
        printf '%s\n' "$REPORT_OUT" | grep -qF "$section" || {
            echo "verify.sh: report output missing \`$section\`" >&2
            printf '%s\n' "$REPORT_OUT" >&2
            exit 1
        }
    done
    # Chaos smoke: chaos-100 exercises the fault-injection path (decode
    # retries, straggle, checkpoint corruption + the .prev recovery
    # ladder) while chaos-panic deliberately poisons its unit with an
    # injected client panic. The sweep must DRAIN the fleet — chaos-100
    # completes with a trace, chaos-panic lands as exactly one `failed`
    # row — and only then exit non-zero (docs/FAULTS.md).
    echo "== chaos sweep smoke (chaos-100 ok, chaos-panic failed row) =="
    CHAOS_OUT="$(mktemp -d)"
    trap 'rm -rf "$SWEEP_OUT" "$CHAOS_OUT"' EXIT
    if cargo run --release --quiet -- sweep \
        --scenarios chaos-100,chaos-panic --algorithms qccf \
        --seeds 1 --quick --profile tiny --threads 2 \
        --checkpoint-every 1 --out "$CHAOS_OUT"; then
        echo "verify.sh: chaos sweep exited zero despite chaos-panic" >&2
        exit 1
    fi
    [ -s "$CHAOS_OUT"/chaos-100__qccf__seed1.jsonl ] || {
        echo "verify.sh: chaos sweep missing chaos-100 trace" >&2
        exit 1
    }
    n_failed="$(grep -c ',failed,' "$CHAOS_OUT"/summary.csv || true)"
    [ "$n_failed" = "1" ] || {
        echo "verify.sh: chaos sweep expected 1 failed row, got $n_failed" >&2
        exit 1
    }
    # Resume over the same --out: the chaos-100 `ok` row is carried, the
    # `failed` chaos-panic row re-runs (and fails again), so the exit
    # stays non-zero and the summary still holds exactly one failed row.
    echo "== chaos sweep --resume smoke (ok row carried, failed re-run) =="
    if cargo run --release --quiet -- sweep \
        --scenarios chaos-100,chaos-panic --algorithms qccf \
        --seeds 1 --quick --profile tiny --threads 2 \
        --checkpoint-every 1 --out "$CHAOS_OUT" --resume; then
        echo "verify.sh: chaos sweep --resume exited zero despite chaos-panic" >&2
        exit 1
    fi
    grep -q '^chaos-100,' "$CHAOS_OUT"/summary.csv || {
        echo "verify.sh: chaos sweep --resume lost the chaos-100 row" >&2
        exit 1
    }
    n_failed="$(grep -c ',failed,' "$CHAOS_OUT"/summary.csv || true)"
    [ "$n_failed" = "1" ] || {
        echo "verify.sh: chaos --resume expected 1 failed row, got $n_failed" >&2
        exit 1
    }
else
    echo "== sweep smoke skipped (no artifacts/manifest.json — run make artifacts) =="
fi
echo "== verify OK =="
