#!/usr/bin/env bash
# Tier-1 gate in one command (see ROADMAP.md):
#
#   ./verify.sh
#
# Runs the release build, the full test suite, and clippy with warnings
# denied, from wherever the Cargo manifest lives relative to this repo.
set -euo pipefail
cd "$(dirname "$0")"

# The crate roots at the repo top level (rust/src via the manifest); fall
# back to rust/ if a standalone manifest is ever introduced there. The
# authoring container has no cargo toolchain — this gate is for the CI /
# toolchain image that carries the manifest and the vendored xla crate.
if [ -f Cargo.toml ]; then
    :
elif [ -f rust/Cargo.toml ]; then
    cd rust
else
    echo "verify.sh: no Cargo.toml found at repo root or rust/" >&2
    echo "verify.sh: run from the toolchain image (see ROADMAP.md tier-1)" >&2
    exit 1
fi

echo "== cargo build --release =="
cargo build --release
echo "== cargo test -q =="
cargo test -q
echo "== cargo clippy --all-targets -- -D warnings =="
cargo clippy --all-targets -- -D warnings
echo "== verify OK =="
