"""Layer-1 Pallas kernels + pure-jnp oracles (ref.py)."""

from .matmul import matmul
from .quantize import stochastic_quantize
from .sgd import sgd_update

__all__ = ["matmul", "stochastic_quantize", "sgd_update"]
