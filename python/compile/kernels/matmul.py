"""Layer-1 Pallas kernel: tiled matmul for the dense model head.

Classic MXU-shaped tiling: grid ``(M/bm, N/bn, K/bk)`` with an f32
accumulator in the output tile, K innermost so each output tile is
initialized on the first K step and accumulated in place.  On TPU the
128x128 tiles map onto the systolic array and the BlockSpecs express the
HBM->VMEM schedule; here interpret-mode lowering turns the same structure
into plain HLO (DESIGN.md §7 — hardware adaptation).

Autodiff: ``pallas_call`` has no automatic VJP, so :func:`matmul` is a
``jax.custom_vjp`` whose backward pass reuses the same kernel
(``dx = g @ w^T``, ``dw = x^T @ g``) — both forward and backward of every
dense layer in the model run through this kernel.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

TILE = 128


def _ceil_to(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


def _pick(dim: int, cap: int = TILE) -> int:
    """Tile size: next power of two covering ``dim``, capped at ``cap``."""
    t = 1
    while t < dim and t < cap:
        t *= 2
    return t


def _mm_kernel(x_ref, w_ref, o_ref):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        x_ref[...], w_ref[...], preferred_element_type=jnp.float32
    )


def _pallas_matmul(x, w):
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, (x.shape, w.shape)
    # K-tile cap 1024 (perf pass, EXPERIMENTS.md §Perf): halves the
    # K-grid trips of the dense layers for a ≤ 512 KiB per-operand tile —
    # still ~1% of TPU VMEM double-buffered, −10% train_step wall clock
    # under interpret-mode lowering.
    bm, bn, bk = _pick(m), _pick(n), _pick(k, 1024)
    mp, np_, kp = _ceil_to(m, bm), _ceil_to(n, bn), _ceil_to(k, bk)
    xp = jnp.pad(x.astype(jnp.float32), ((0, mp - m), (0, kp - k)))
    wp = jnp.pad(w.astype(jnp.float32), ((0, kp - k), (0, np_ - n)))
    out = pl.pallas_call(
        _mm_kernel,
        grid=(mp // bm, np_ // bn, kp // bk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, s: (i, s)),
            pl.BlockSpec((bk, bn), lambda i, j, s: (s, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, s: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        interpret=True,
    )(xp, wp)
    return out[:m, :n]


@jax.custom_vjp
def matmul(x, w):
    """``x @ w`` through the tiled Pallas kernel (f32)."""
    return _pallas_matmul(x, w)


def _mm_fwd(x, w):
    return _pallas_matmul(x, w), (x, w)


def _mm_bwd(res, g):
    x, w = res
    return _pallas_matmul(g, w.T), _pallas_matmul(x.T, g)


matmul.defvjp(_mm_fwd, _mm_bwd)
