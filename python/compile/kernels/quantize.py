"""Layer-1 Pallas kernel: stochastic quantization (paper eq. (4)).

The kernel streams the flat parameter vector through VMEM-sized 1-D blocks
(``BLOCK`` elements per grid step) and snaps every element onto the
``2^q - 1`` knot grid with stochastic rounding.  The rounding decision uses
an *explicit* uniform-noise input so that

* the Rust coordinator owns the randomness (xoshiro256++ stream per client
  per round) and the whole simulation is reproducible end-to-end, and
* the pure-jnp oracle in :mod:`ref` can be compared bit-for-bit.

The quantization level ``q`` and the L-inf range ``theta_max`` are runtime
scalars, so a single AOT-lowered artifact serves every level the QCCF
solver picks (q changes per client per round — eq. (41)).

On a real TPU the 1-D grid expresses the HBM->VMEM double-buffering
schedule; here the kernel is lowered with ``interpret=True`` into plain HLO
(the CPU PJRT client cannot execute Mosaic custom-calls), so correctness is
the signal and the BlockSpec structure is the TPU story (see DESIGN.md §7).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# 4096 f32 = 16 KiB per operand block; with theta + noise + out double
# buffered this stays far under the ~16 MiB VMEM budget of a TPU core.
BLOCK = 4096


def _quantize_kernel(scale_ref, theta_ref, noise_ref, o_ref):
    """One block: snap |theta| / theta_max onto the knot grid (eq. (4))."""
    t = theta_ref[...]
    u = noise_ref[...]
    safe_max = scale_ref[0]
    levels = scale_ref[1]
    scaled = jnp.abs(t) / safe_max * levels  # in [0, levels]
    low = jnp.floor(scaled)
    frac = scaled - low
    # P[round up] = frac  (eq. (4) second branch probability).
    knot = low + (u < frac).astype(jnp.float32)
    o_ref[...] = jnp.sign(t) * knot / levels * safe_max


def stochastic_quantize(theta, noise, q, *, block=BLOCK):
    """Quantize ``theta`` with ``q`` bits; returns ``(dequantized, theta_max)``.

    Args:
      theta: f32[Z] flat parameter vector.
      noise: f32[Z] uniforms in [0, 1).
      q:     f32 scalar quantization level (bits, >= 1). Runtime value.
      block: elements per grid step (VMEM tile).

    Matches :func:`ref.stochastic_quantize_ref` bit-for-bit.
    """
    theta = theta.astype(jnp.float32)
    noise = noise.astype(jnp.float32)
    (z,) = theta.shape
    theta_max = jnp.max(jnp.abs(theta))
    levels = jnp.exp2(jnp.asarray(q, jnp.float32)) - 1.0
    safe_max = jnp.where(theta_max > 0.0, theta_max, 1.0)
    scale = jnp.stack([safe_max, levels])

    zp = max(block, ((z + block - 1) // block) * block)
    tp = jnp.pad(theta, (0, zp - z))
    up = jnp.pad(noise, (0, zp - z))
    out = pl.pallas_call(
        _quantize_kernel,
        grid=(zp // block,),
        in_specs=[
            pl.BlockSpec((2,), lambda i: (0,)),
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((zp,), jnp.float32),
        interpret=True,
    )(scale, tp, up)
    deq = out[:z]
    deq = jnp.where(theta_max > 0.0, deq, jnp.zeros_like(deq))
    return deq, theta_max
