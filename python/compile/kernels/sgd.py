"""Layer-1 Pallas kernel: fused SGD update (paper eq. (1) inner write).

``theta <- theta - lr * grad`` over the flat parameter vector, tiled into
VMEM-sized blocks.  This is the hot write of every local update: it runs
``tau`` times per participating client per communication round, inside the
AOT-lowered ``train_step``.

Lowered with ``interpret=True`` (see quantize.py for why).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK = 4096


def _sgd_kernel(lr_ref, theta_ref, grad_ref, o_ref):
    o_ref[...] = theta_ref[...] - lr_ref[0] * grad_ref[...]


def sgd_update(theta, grad, lr, *, block=BLOCK):
    """Fused ``theta - lr * grad``; matches :func:`ref.sgd_update_ref`.

    Args:
      theta: f32[Z] flat parameters.
      grad:  f32[Z] flat gradient.
      lr:    f32 scalar learning rate (runtime value).
    """
    theta = theta.astype(jnp.float32)
    grad = grad.astype(jnp.float32)
    (z,) = theta.shape
    lr = jnp.reshape(jnp.asarray(lr, jnp.float32), (1,))
    zp = max(block, ((z + block - 1) // block) * block)
    tp = jnp.pad(theta, (0, zp - z))
    gp = jnp.pad(grad, (0, zp - z))
    out = pl.pallas_call(
        _sgd_kernel,
        grid=(zp // block,),
        in_specs=[
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((zp,), jnp.float32),
        interpret=True,
    )(lr, tp, gp)
    return out[:z]
