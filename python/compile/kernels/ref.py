"""Pure-jnp oracles for every Pallas kernel in this package.

These are the correctness ground truth: pytest (and hypothesis sweeps)
assert the Pallas kernels in ``quantize.py`` / ``sgd.py`` / ``matmul.py``
match these implementations bit-for-bit (quantize, sgd) or to float
tolerance (matmul).

They mirror the paper's equations directly:

* :func:`stochastic_quantize_ref` — eq. (4): q-bit stochastic quantization
  of each dimension against the vector's L-inf range ``theta_max``, with an
  *explicit* uniform noise input so the stochastic rounding decision is
  reproducible (the Rust coordinator supplies the noise from its own RNG).
* :func:`sgd_update_ref` — the inner write of eq. (1).
* :func:`matmul_ref` — dense head matmul.
"""

from __future__ import annotations

import jax.numpy as jnp


def quant_levels(q):
    """Number of intervals ``2^q - 1`` for a q-bit level, as f32.

    ``q`` is a runtime scalar (f32) so one lowered artifact serves every
    quantization level the coordinator picks.
    """
    return jnp.exp2(q) - 1.0


def stochastic_quantize_ref(theta, noise, q):
    """Eq. (4) of the paper, vectorized over the flat parameter vector.

    Args:
      theta: f32[Z] flat parameter vector.
      noise: f32[Z] i.i.d. uniforms in [0, 1) deciding the rounding.
      q:     f32 scalar quantization level (bits), q >= 1.

    Returns:
      (dequantized f32[Z] — values snapped onto the 2^q - 1 knot grid with
      stochastic rounding, f32 scalar theta_max).

    The wire format (range float + signs + knot indices, eq. (5)) is
    accounted analytically on the Rust side; the simulation moves the
    dequantized values, which is exactly what the server reconstructs.
    """
    theta = theta.astype(jnp.float32)
    theta_max = jnp.max(jnp.abs(theta))
    levels = quant_levels(q)
    # Guard: theta_max == 0 -> everything quantizes to 0.
    safe_max = jnp.where(theta_max > 0.0, theta_max, 1.0)
    scaled = jnp.abs(theta) / safe_max * levels  # in [0, levels]
    low = jnp.floor(scaled)
    frac = scaled - low
    up = (noise < frac).astype(jnp.float32)
    knot = low + up
    deq = jnp.sign(theta) * knot / levels * safe_max
    deq = jnp.where(theta_max > 0.0, deq, jnp.zeros_like(theta))
    return deq.astype(jnp.float32), theta_max.astype(jnp.float32)


def sgd_update_ref(theta, grad, lr):
    """theta <- theta - lr * grad (eq. (1) inner step)."""
    return (theta - lr * grad).astype(jnp.float32)


def matmul_ref(x, w):
    """f32 matmul oracle for the tiled Pallas matmul."""
    return jnp.matmul(x.astype(jnp.float32), w.astype(jnp.float32))
