"""Layer-2 JAX model: the paper's CNNs with a flat-parameter interface.

The Rust coordinator moves a single ``f32[Z]`` buffer per client; this
module defines the profile-parameterized CNN (paper §VI *Models*), the
flatten/unflatten bijection, and the four AOT entry points lowered by
``aot.py``:

* ``init()                            -> theta[Z]``
* ``train_step(theta, xs, ys, lr)     -> (theta', mean_loss, gnorms[tau])``
    — tau local mini-batch SGD steps (paper eq. (1)) via ``lax.scan``;
      dense layers through the Pallas ``matmul`` kernel, the parameter
      write through the Pallas ``sgd_update`` kernel; per-step gradient
      norms feed the coordinator's G_i / sigma_i estimators (§III).
* ``eval_step(theta, x, y, w)         -> (sum_loss, n_correct, n)``
    — masked so the Rust side can pad the last test chunk.
* ``quantize(theta, noise, q)         -> (Q(theta), theta_max)``
    — paper eq. (4) through the Pallas ``stochastic_quantize`` kernel.

Profiles (DESIGN.md §5): ``femnist`` and ``cifar`` reproduce the paper's
architectures *exactly* (Z = 246 590 and 576 778, matching Table I);
``tiny``/``small`` are downscaled versions of the same topology for this
1-core CPU box.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Dict, List, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from .kernels import matmul, sgd_update, stochastic_quantize


@dataclasses.dataclass(frozen=True)
class Profile:
    """One model/workload configuration, lowered to its own artifact set."""

    name: str
    image: Tuple[int, int, int]  # (H, W, C)
    classes: int
    conv: Tuple[int, ...]  # output channels of each 5x5 conv (+2x2 maxpool)
    extra_pools: int  # additional 2x2 pools after the conv stack
    fc: Tuple[int, ...]  # hidden dense widths after flatten (excl. classes)
    batch: int  # local mini-batch size B
    eval_batch: int  # test chunk size fed to eval_step
    tau: int  # local updates per round (paper tau)
    tau_e: int  # local epochs (tau is a multiple of tau_e)
    lr: float  # default learning rate eta
    # Gradient-norm clip enforcing the paper's Assumption 1
    # (||grad F_i|| <= G_i): without it, an aggressive early quantization
    # (q = 1) can blow the loss up and the G/sigma estimates the
    # coordinator feeds the Lyapunov machinery diverge.
    clip: float = 5.0


# femnist: conv32-conv64, flatten 7*7*64 = 3136 ("hidden layer with 3136
# neurons"), fc -> 62.   Z = 832 + 51 264 + 194 494 = 246 590  (Table I).
# cifar:   conv64-conv64 + one extra pool, flatten 4*4*64 = 1024, hidden
# 384, 192, fc -> 10.    Z = 4 864 + 102 464 + 393 600*...  = 576 778.
PROFILES: Dict[str, Profile] = {
    p.name: p
    for p in [
        Profile("tiny", (8, 8, 1), 10, (4, 8), 0, (), 8, 64, 6, 2, 0.05),
        Profile("small", (16, 16, 1), 10, (8, 16), 0, (64,), 16, 128, 6, 2, 0.05),
        Profile("femnist", (28, 28, 1), 62, (32, 64), 0, (), 20, 128, 6, 2, 0.03),
        Profile("cifar", (32, 32, 3), 10, (64, 64), 1, (384, 192), 20, 128, 6, 2, 0.03),
    ]
}


# --------------------------------------------------------------------------
# Parameter shapes / flatten / unflatten
# --------------------------------------------------------------------------


def param_shapes(p: Profile) -> List[Tuple[str, Tuple[int, ...]]]:
    """Ordered (name, shape) list defining the flat-vector layout."""
    shapes: List[Tuple[str, Tuple[int, ...]]] = []
    h, w, cin = p.image
    for li, cout in enumerate(p.conv):
        shapes.append((f"conv{li}_w", (5, 5, cin, cout)))
        shapes.append((f"conv{li}_b", (cout,)))
        cin = cout
        h, w = h // 2, w // 2  # 2x2 maxpool after every conv
    for _ in range(p.extra_pools):
        h, w = h // 2, w // 2
    feat = h * w * cin
    for li, width in enumerate(p.fc):
        shapes.append((f"fc{li}_w", (feat, width)))
        shapes.append((f"fc{li}_b", (width,)))
        feat = width
    shapes.append(("out_w", (feat, p.classes)))
    shapes.append(("out_b", (p.classes,)))
    return shapes


def num_params(p: Profile) -> int:
    return sum(int(jnp.prod(jnp.array(s))) for _, s in param_shapes(p))


def unflatten(p: Profile, flat):
    params = {}
    off = 0
    for name, shape in param_shapes(p):
        size = 1
        for d in shape:
            size *= d
        params[name] = flat[off : off + size].reshape(shape)
        off += size
    return params


def flatten_tree(p: Profile, params) -> jnp.ndarray:
    return jnp.concatenate(
        [params[name].reshape(-1) for name, _ in param_shapes(p)]
    )


def init_flat(p: Profile, seed: int = 0) -> jnp.ndarray:
    """He-style init, deterministic per (profile, seed)."""
    key = jax.random.PRNGKey(seed)
    parts = []
    for name, shape in param_shapes(p):
        key, sub = jax.random.split(key)
        if name.endswith("_b"):
            parts.append(jnp.zeros(shape, jnp.float32).reshape(-1))
        else:
            fan_in = 1
            for d in shape[:-1]:
                fan_in *= d
            std = jnp.sqrt(2.0 / fan_in)
            parts.append(
                (jax.random.normal(sub, shape, jnp.float32) * std).reshape(-1)
            )
    return jnp.concatenate(parts)


# --------------------------------------------------------------------------
# Forward / loss
# --------------------------------------------------------------------------


def _maxpool2(x):
    return lax.reduce_window(
        x, -jnp.inf, lax.max, (1, 2, 2, 1), (1, 2, 2, 1), "VALID"
    )


def forward(p: Profile, params, x):
    """Logits for a batch of NHWC images (conv-relu-pool stack + dense head).

    Convs are ``lax.conv_general_dilated`` (plain XLA); every dense layer
    goes through the Pallas ``matmul`` kernel (fwd *and* bwd, via its
    custom_vjp).
    """
    h = x.astype(jnp.float32)
    for li in range(len(p.conv)):
        h = lax.conv_general_dilated(
            h,
            params[f"conv{li}_w"],
            window_strides=(1, 1),
            padding="SAME",
            dimension_numbers=("NHWC", "HWIO", "NHWC"),
        )
        h = jax.nn.relu(h + params[f"conv{li}_b"])
        h = _maxpool2(h)
    for _ in range(p.extra_pools):
        h = _maxpool2(h)
    h = h.reshape(h.shape[0], -1)
    for li in range(len(p.fc)):
        h = jax.nn.relu(matmul(h, params[f"fc{li}_w"]) + params[f"fc{li}_b"])
    return matmul(h, params["out_w"]) + params["out_b"]


def loss_fn(p: Profile, flat, x, y):
    """Mean softmax cross-entropy of the flat parameter vector."""
    logits = forward(p, unflatten(p, flat), x)
    logp = jax.nn.log_softmax(logits)
    nll = -jnp.take_along_axis(logp, y[:, None].astype(jnp.int32), axis=1)
    return jnp.mean(nll)


# --------------------------------------------------------------------------
# AOT entry points
# --------------------------------------------------------------------------


def train_step(p: Profile, flat, xs, ys, lr):
    """tau local SGD steps (paper eq. (1)).

    Args:
      flat: f32[Z] parameters (theta^{n-1} broadcast by the server).
      xs:   f32[tau, B, H, W, C] mini-batches sampled by the coordinator.
      ys:   i32[tau, B] labels.
      lr:   f32 scalar eta.

    Returns:
      (f32[Z] theta^{n,tau}, f32 mean loss, f32[tau] per-step grad norms).
    """

    def body(theta, batch):
        x, y = batch
        loss, grad = jax.value_and_grad(lambda t: loss_fn(p, t, x, y))(theta)
        gnorm = jnp.sqrt(jnp.sum(grad * grad))
        # Assumption-1 clip: scale the step so ||g|| <= clip.
        scale = jnp.minimum(1.0, p.clip / (gnorm + 1e-12))
        theta = sgd_update(theta, grad * scale, lr)
        return theta, (loss, jnp.minimum(gnorm, p.clip))

    flat, (losses, gnorms) = lax.scan(body, flat, (xs, ys))
    return flat, jnp.mean(losses), gnorms


def eval_step(p: Profile, flat, x, y, w):
    """Masked eval chunk: returns (sum weighted loss, n correct, n valid)."""
    logits = forward(p, unflatten(p, flat), x)
    logp = jax.nn.log_softmax(logits)
    nll = -jnp.take_along_axis(logp, y[:, None].astype(jnp.int32), axis=1)[:, 0]
    pred = jnp.argmax(logits, axis=1).astype(jnp.int32)
    correct = (pred == y.astype(jnp.int32)).astype(jnp.float32) * w
    return jnp.sum(nll * w), jnp.sum(correct), jnp.sum(w)


def quantize(p: Profile, flat, noise, q):
    """Paper eq. (4) over the flat vector (Pallas kernel)."""
    return stochastic_quantize(flat, noise, q)


def entry_points(p: Profile, seed: int = 0):
    """(name, fn, example_args) for every artifact lowered by aot.py."""
    z = num_params(p)
    h, w, c = p.image
    f32, i32 = jnp.float32, jnp.int32
    theta = jax.ShapeDtypeStruct((z,), f32)
    xs = jax.ShapeDtypeStruct((p.tau, p.batch, h, w, c), f32)
    ys = jax.ShapeDtypeStruct((p.tau, p.batch), i32)
    xe = jax.ShapeDtypeStruct((p.eval_batch, h, w, c), f32)
    ye = jax.ShapeDtypeStruct((p.eval_batch,), i32)
    we = jax.ShapeDtypeStruct((p.eval_batch,), f32)
    scalar = jax.ShapeDtypeStruct((), f32)
    return [
        ("init", lambda: (init_flat(p, seed),), ()),
        (
            "train_step",
            lambda t, x, y, lr: train_step(p, t, x, y, lr),
            (theta, xs, ys, scalar),
        ),
        (
            "eval_step",
            lambda t, x, y, w_: eval_step(p, t, x, y, w_),
            (theta, xe, ye, we),
        ),
        (
            "quantize",
            lambda t, u, q: quantize(p, t, u, q),
            (theta, theta, scalar),
        ),
    ]
