"""AOT lowering: JAX/Pallas -> HLO *text* artifacts + manifest.json.

Run once at build time (``make artifacts``); the Rust coordinator loads the
text with ``HloModuleProto::from_text_file`` and compiles it on the PJRT
CPU client.  HLO **text** (never ``.serialize()``) is the interchange
format: jax >= 0.5 emits protos with 64-bit instruction ids which
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly.

Usage:
    python -m compile.aot --out ../artifacts --profiles tiny small
"""

from __future__ import annotations

import argparse
import json
import os

import jax
from jax._src.lib import xla_client as xc

from . import model


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _arg_desc(spec) -> dict:
    return {"shape": list(spec.shape), "dtype": str(spec.dtype)}


def lower_profile(profile: model.Profile, out_dir: str, seed: int) -> dict:
    """Lower every entry point of one profile; returns its manifest stanza."""
    pdir = os.path.join(out_dir, profile.name)
    os.makedirs(pdir, exist_ok=True)
    arts = {}
    for name, fn, args in model.entry_points(profile, seed):
        lowered = jax.jit(fn).lower(*args)
        text = to_hlo_text(lowered)
        fname = f"{name}.hlo.txt"
        with open(os.path.join(pdir, fname), "w") as f:
            f.write(text)
        arts[name] = {
            "file": fname,
            "args": [_arg_desc(a) for a in args],
        }
        print(f"  {profile.name}/{fname}: {len(text)} chars")
    h, w, c = profile.image
    return {
        "z": model.num_params(profile),
        "tau": profile.tau,
        "tau_e": profile.tau_e,
        "batch": profile.batch,
        "eval_batch": profile.eval_batch,
        "image": [h, w, c],
        "classes": profile.classes,
        "lr": profile.lr,
        "seed": seed,
        "artifacts": arts,
    }


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument(
        "--profiles", nargs="+", default=["tiny", "small"],
        choices=sorted(model.PROFILES),
    )
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    os.makedirs(args.out, exist_ok=True)
    manifest_path = os.path.join(args.out, "manifest.json")
    manifest = {}
    if os.path.exists(manifest_path):
        with open(manifest_path) as f:
            manifest = json.load(f)
    for pname in args.profiles:
        print(f"lowering profile {pname} ...")
        manifest[pname] = lower_profile(model.PROFILES[pname], args.out, args.seed)
    with open(manifest_path, "w") as f:
        json.dump(manifest, f, indent=2, sort_keys=True)
    print(f"wrote {manifest_path}")


if __name__ == "__main__":
    main()
