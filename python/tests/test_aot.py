"""AOT pipeline checks: HLO text round-trips through the XLA parser and the
manifest agrees with the model's parameter accounting.

These run against the checked-out ``artifacts/`` tree if ``make artifacts``
has been run; otherwise they lower the tiny profile into a tmpdir.
"""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, model

ARTIFACTS = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


def _manifest():
    path = os.path.join(ARTIFACTS, "manifest.json")
    if not os.path.exists(path):
        pytest.skip("run `make artifacts` first")
    with open(path) as f:
        return json.load(f)


def test_manifest_z_matches_model():
    man = _manifest()
    for name, stanza in man.items():
        assert stanza["z"] == model.num_params(model.PROFILES[name]), name


def test_manifest_artifacts_exist_and_nonempty():
    man = _manifest()
    for name, stanza in man.items():
        for art in stanza["artifacts"].values():
            path = os.path.join(ARTIFACTS, name, art["file"])
            assert os.path.getsize(path) > 100, path


def test_hlo_text_is_parseable_header():
    man = _manifest()
    for name, stanza in man.items():
        path = os.path.join(ARTIFACTS, name, stanza["artifacts"]["quantize"]["file"])
        with open(path) as f:
            head = f.read(200)
        assert head.startswith("HloModule"), head[:40]


def test_train_step_arg_shapes_in_manifest():
    man = _manifest()
    for name, stanza in man.items():
        p = model.PROFILES[name]
        args = stanza["artifacts"]["train_step"]["args"]
        assert args[0]["shape"] == [stanza["z"]]
        assert args[1]["shape"] == [p.tau, p.batch, *p.image]
        assert args[2]["shape"] == [p.tau, p.batch]
        assert args[2]["dtype"] == "int32"


def test_lowered_quantize_executes_like_eager():
    """Compile the lowered HLO text back through XLA and compare numerics."""
    p = model.PROFILES["tiny"]
    z = model.num_params(p)
    fn = lambda t, u, q: model.quantize(p, t, u, q)
    lowered = jax.jit(fn).lower(
        jax.ShapeDtypeStruct((z,), jnp.float32),
        jax.ShapeDtypeStruct((z,), jnp.float32),
        jax.ShapeDtypeStruct((), jnp.float32),
    )
    text = aot.to_hlo_text(lowered)
    assert "HloModule" in text and len(text) > 1000
    theta = model.init_flat(p, 0)
    noise = jax.random.uniform(jax.random.PRNGKey(1), (z,))
    want, wmax = model.quantize(p, theta, noise, 3.0)
    got, gmax = jax.jit(fn)(theta, noise, 3.0)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    assert float(wmax) == float(gmax)
