"""L2 model checks: parameter accounting (paper Table I Z values),
flatten/unflatten bijection, training-step semantics, eval masking."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model

jax.config.update("jax_platform_name", "cpu")

TINY = model.PROFILES["tiny"]


def _toy_data(p, seed=0, n=None):
    """Linearly-separable-ish blobs so a few SGD steps must reduce loss."""
    h, w, c = p.image
    n = n or p.batch
    key = jax.random.PRNGKey(seed)
    y = jax.random.randint(key, (n,), 0, p.classes)
    protos = jax.random.normal(jax.random.PRNGKey(7), (p.classes, h, w, c))
    x = protos[y] + 0.1 * jax.random.normal(jax.random.PRNGKey(seed + 1), (n, h, w, c))
    return x, y.astype(jnp.int32)


# -------------------------------------------------------- param accounting


def test_paper_z_femnist():
    """Paper Table I: Z^FEMNIST = 246590 — our architecture matches exactly."""
    assert model.num_params(model.PROFILES["femnist"]) == 246590


def test_paper_z_cifar():
    """Paper Table I: Z^CIFAR-10 = 576778."""
    assert model.num_params(model.PROFILES["cifar"]) == 576778


@pytest.mark.parametrize("name", sorted(model.PROFILES))
def test_flatten_roundtrip(name):
    p = model.PROFILES[name]
    z = model.num_params(p)
    flat = jnp.arange(z, dtype=jnp.float32)
    back = model.flatten_tree(p, model.unflatten(p, flat))
    np.testing.assert_array_equal(np.asarray(back), np.asarray(flat))


@pytest.mark.parametrize("name", sorted(model.PROFILES))
def test_init_shape_and_determinism(name):
    p = model.PROFILES[name]
    a = model.init_flat(p, 0)
    b = model.init_flat(p, 0)
    assert a.shape == (model.num_params(p),)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    c = model.init_flat(p, 1)
    assert not np.array_equal(np.asarray(a), np.asarray(c))


def test_init_biases_zero():
    p = TINY
    params = model.unflatten(p, model.init_flat(p, 0))
    for name, _ in model.param_shapes(p):
        if name.endswith("_b"):
            np.testing.assert_array_equal(np.asarray(params[name]), 0.0)


# ------------------------------------------------------------- train_step


def test_train_step_reduces_loss():
    p = TINY
    flat = model.init_flat(p, 0)
    x, y = _toy_data(p, n=p.batch * p.tau)
    xs = x.reshape(p.tau, p.batch, *p.image)
    ys = y.reshape(p.tau, p.batch)
    step = jax.jit(lambda t: model.train_step(p, t, xs, ys, p.lr))
    l0 = float(model.loss_fn(p, flat, x, y))
    for _ in range(8):
        flat, loss, gnorms = step(flat)
    l1 = float(model.loss_fn(p, flat, x, y))
    assert l1 < l0 * 0.8, (l0, l1)
    assert gnorms.shape == (p.tau,)
    assert bool(jnp.all(gnorms > 0))


def test_train_step_zero_lr_is_identity():
    p = TINY
    flat = model.init_flat(p, 0)
    x, y = _toy_data(p, n=p.batch * p.tau)
    xs = x.reshape(p.tau, p.batch, *p.image)
    ys = y.reshape(p.tau, p.batch)
    out, _, _ = model.train_step(p, flat, xs, ys, 0.0)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(flat))


def test_train_step_matches_manual_sgd():
    """scan-of-(grad, pallas-sgd) == hand-rolled python loop."""
    p = TINY
    flat = model.init_flat(p, 0)
    x, y = _toy_data(p, n=p.batch * p.tau)
    xs = x.reshape(p.tau, p.batch, *p.image)
    ys = y.reshape(p.tau, p.batch)
    got, _, _ = model.train_step(p, flat, xs, ys, 0.05)
    ref = flat
    for m in range(p.tau):
        g = jax.grad(lambda t: model.loss_fn(p, t, xs[m], ys[m]))(ref)
        gnorm = jnp.sqrt(jnp.sum(g * g))
        scale = jnp.minimum(1.0, p.clip / (gnorm + 1e-12))
        ref = ref - 0.05 * scale * g
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), atol=1e-5)


# -------------------------------------------------------------- eval_step


def test_eval_step_mask():
    p = TINY
    flat = model.init_flat(p, 0)
    x, y = _toy_data(p, n=p.eval_batch)
    w = jnp.ones(p.eval_batch).at[p.eval_batch // 2 :].set(0.0)
    loss, correct, n = model.eval_step(p, flat, x, y, w)
    assert float(n) == p.eval_batch // 2
    assert 0 <= float(correct) <= p.eval_batch // 2
    # Masked-out entries must not contribute.
    x2 = x.at[p.eval_batch // 2 :].set(1e3)
    loss2, correct2, _ = model.eval_step(p, flat, x2, y, w)
    np.testing.assert_allclose(float(loss), float(loss2), rtol=1e-5)
    assert float(correct) == float(correct2)


def test_eval_step_perfect_model_counts_all():
    p = TINY
    flat = model.init_flat(p, 0)
    x, y = _toy_data(p, n=p.eval_batch)
    xs = x[: p.batch * p.tau].reshape(p.tau, p.batch, *p.image)
    ys = y[: p.batch * p.tau].reshape(p.tau, p.batch)
    step = jax.jit(lambda t: model.train_step(p, t, xs, ys, p.lr)[0])
    for _ in range(60):
        flat = step(flat)
    _, correct, n = model.eval_step(p, flat, x, y, jnp.ones(p.eval_batch))
    assert float(correct) / float(n) > 0.6


# --------------------------------------------------------------- quantize


def test_model_quantize_roundtrip_error_shrinks_with_q():
    p = TINY
    flat = model.init_flat(p, 0)
    noise = jax.random.uniform(jax.random.PRNGKey(5), flat.shape)
    errs = []
    for q in [1.0, 4.0, 8.0]:
        qf, _ = model.quantize(p, flat, noise, q)
        errs.append(float(jnp.sum((qf - flat) ** 2)))
    assert errs[0] > errs[1] > errs[2]


def test_entry_points_cover_manifest_names():
    names = [n for n, _, _ in model.entry_points(TINY)]
    assert names == ["init", "train_step", "eval_step", "quantize"]
