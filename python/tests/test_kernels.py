"""L1 correctness: Pallas kernels vs the pure-jnp oracles in ref.py.

The quantize kernel must match the oracle *bit-for-bit* (same float ops in
the same order); sgd/matmul are allowed 1-ulp FMA reassociation.
Hypothesis sweeps shapes, quantization levels and value distributions
(zeros, constants, negatives, denormal-ish scales).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import matmul, sgd_update, stochastic_quantize
from compile.kernels import ref

jax.config.update("jax_platform_name", "cpu")


def _rand(key, n, scale=1.0):
    return jax.random.normal(jax.random.PRNGKey(key), (n,)) * scale


# ---------------------------------------------------------------- quantize


@settings(max_examples=25, deadline=None)
@given(
    n=st.integers(min_value=1, max_value=9000),
    q=st.integers(min_value=1, max_value=16),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
    scale=st.sampled_from([1e-6, 1e-2, 1.0, 37.5, 1e4]),
)
def test_quantize_matches_ref(n, q, seed, scale):
    theta = _rand(seed, n, scale)
    noise = jax.random.uniform(jax.random.PRNGKey(seed + 1), (n,))
    a, amax = stochastic_quantize(theta, noise, float(q))
    b, bmax = ref.stochastic_quantize_ref(theta, noise, float(q))
    assert amax == bmax
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.parametrize("block", [8, 64, 4096])
def test_quantize_block_size_invariance(block):
    theta = _rand(3, 1000)
    noise = jax.random.uniform(jax.random.PRNGKey(4), (1000,))
    base, _ = ref.stochastic_quantize_ref(theta, noise, 2.0)
    out, _ = stochastic_quantize(theta, noise, 2.0, block=block)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(base))


def test_quantize_zero_vector():
    theta = jnp.zeros(100)
    noise = jnp.full((100,), 0.5)
    out, tmax = stochastic_quantize(theta, noise, 4.0)
    assert tmax == 0.0
    np.testing.assert_array_equal(np.asarray(out), np.zeros(100))


def test_quantize_knots_on_grid():
    """Quantized values must lie exactly on the 2^q - 1 knot grid (eq. 4)."""
    q = 3.0
    theta = _rand(7, 512)
    noise = jax.random.uniform(jax.random.PRNGKey(8), (512,))
    out, tmax = stochastic_quantize(theta, noise, q)
    levels = 2.0**q - 1.0
    idx = np.asarray(jnp.abs(out) / tmax * levels)
    np.testing.assert_allclose(idx, np.round(idx), atol=1e-4)
    assert np.all(np.abs(np.asarray(out)) <= float(tmax) * (1 + 1e-6))


def test_quantize_unbiased_statistically():
    """Lemma 1: E[Q(theta)] = theta. Average many independent noise draws."""
    theta = _rand(11, 256)
    reps = 600
    keys = jax.random.split(jax.random.PRNGKey(12), reps)
    acc = jnp.zeros_like(theta)
    for k in keys:
        noise = jax.random.uniform(k, theta.shape)
        out, _ = ref.stochastic_quantize_ref(theta, noise, 2.0)
        acc = acc + out
    mean = acc / reps
    tmax = float(jnp.max(jnp.abs(theta)))
    # std of each estimate <= interval/2/sqrt(reps)
    tol = tmax / (2**2 - 1) / np.sqrt(reps) * 5
    np.testing.assert_allclose(np.asarray(mean), np.asarray(theta), atol=tol)


def test_quantize_variance_bound_lemma1():
    """Lemma 1: E||Q(t) - t||^2 <= Z * tmax^2 / (4 (2^q - 1)^2)."""
    theta = _rand(13, 400)
    tmax = float(jnp.max(jnp.abs(theta)))
    for q in [1.0, 2.0, 5.0]:
        errs = []
        for s in range(40):
            noise = jax.random.uniform(jax.random.PRNGKey(100 + s), theta.shape)
            out, _ = ref.stochastic_quantize_ref(theta, noise, q)
            errs.append(float(jnp.sum((out - theta) ** 2)))
        bound = 400 * tmax**2 / (4 * (2.0**q - 1) ** 2)
        assert np.mean(errs) <= bound * 1.05


def test_quantize_high_q_near_identity():
    theta = _rand(17, 300)
    noise = jax.random.uniform(jax.random.PRNGKey(18), (300,))
    out, tmax = stochastic_quantize(theta, noise, 16.0)
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(theta), atol=float(tmax) / (2**16 - 1) * 1.01
    )


# -------------------------------------------------------------------- sgd


@settings(max_examples=15, deadline=None)
@given(
    n=st.integers(min_value=1, max_value=9000),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
    lr=st.sampled_from([0.0, 1e-4, 0.05, 1.0]),
)
def test_sgd_matches_ref(n, seed, lr):
    theta = _rand(seed, n)
    grad = _rand(seed + 1, n)
    a = sgd_update(theta, grad, lr)
    b = ref.sgd_update_ref(theta, grad, lr)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6, rtol=1e-6)


def test_sgd_zero_lr_identity():
    theta = _rand(19, 500)
    grad = _rand(20, 500)
    out = sgd_update(theta, grad, 0.0)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(theta))


# ----------------------------------------------------------------- matmul


@settings(max_examples=15, deadline=None)
@given(
    m=st.integers(min_value=1, max_value=70),
    k=st.integers(min_value=1, max_value=300),
    n=st.integers(min_value=1, max_value=200),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_matmul_matches_ref(m, k, n, seed):
    x = jax.random.normal(jax.random.PRNGKey(seed), (m, k))
    w = jax.random.normal(jax.random.PRNGKey(seed + 1), (k, n))
    a = matmul(x, w)
    b = ref.matmul_ref(x, w)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-3, rtol=1e-4)


def test_matmul_grad_matches_ref():
    x = jax.random.normal(jax.random.PRNGKey(21), (16, 100))
    w = jax.random.normal(jax.random.PRNGKey(22), (100, 62))
    ga = jax.grad(lambda x, w: jnp.sum(matmul(x, w) ** 2), argnums=(0, 1))(x, w)
    gb = jax.grad(lambda x, w: jnp.sum(ref.matmul_ref(x, w) ** 2), argnums=(0, 1))(
        x, w
    )
    for a, b in zip(ga, gb):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-2, rtol=1e-3)


def test_matmul_large_k_accumulation():
    """K spans several 512-wide tiles: accumulation across grid steps."""
    x = jnp.ones((4, 1500))
    w = jnp.ones((1500, 8))
    out = matmul(x, w)
    np.testing.assert_allclose(np.asarray(out), np.full((4, 8), 1500.0), rtol=1e-6)


def test_matmul_jit_under_scan():
    """The kernel must lower inside jit+scan (same path as train_step)."""

    def step(c, _):
        return matmul(c, jnp.eye(8)), None

    out, _ = jax.jit(lambda c: jax.lax.scan(step, c, None, length=3))(
        jnp.arange(16.0).reshape(2, 8)
    )
    np.testing.assert_allclose(np.asarray(out), np.arange(16.0).reshape(2, 8))
